"""The melt-fusing planner: op chain → minimum-pass step program.

Three fusion rules (DESIGN.md §11):

1. **Weight composition** — adjacent linear stages merge into ONE
   operator-bank column when the rewrite is *exact*: both stages stride-1,
   dilation-1, ``padding='valid'``, and the earlier stage single-column
   (K=1).  In the melt's absolute-index form the composite weights are the
   full N-D convolution of the two operator tensors
   (``comp[a] = Σ_{a1+a2=a} w1[a1]·w2[a2]``), footprint ``k1+k2−1`` per
   dim.  Fusion is *declined* — stages stay separate passes — for 'same'
   padding (any fill: boundary semantics do not compose), strided or
   dilated stages, and K>1 predecessors.

2. **Trailing-reduction fusion** — a terminal ``moments``/``hist``/``cov``
   consumes the producing group's value inside the same executor: the
   intermediate is never re-melted (0 extra melt passes on the
   materialize path; never leaves the compiled computation on lax/fused).

3. **Separable rewrite** — each planned group's final weight matrix is
   re-examined with ``separable_factors``: bank-kind and composed groups
   whose columns are rank-1 outer products run as per-dim 1-D passes past
   the ``separable_profitable`` crossover ('same' needs a zero/mode fill;
   'valid' is unconditionally exact).  Plain ``.stencil``/``.gaussian``
   stages stay dense for parity with ``apply_stencil``.

The program records ``passes`` (logical fused traversals) and
``melt_calls`` (the exact ``melt()`` count the materialize path pays:
separable groups pay one 1-D melt per dim) — the numbers the no-extra-melt
tests assert against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.grid import QuasiGrid, make_quasi_grid
from repro.core.plan import ExecOptions, separable_profitable
from repro.pipe.graph import (
    CovOp,
    HistOp,
    LinearOp,
    MomentsOp,
    Pipe,
    PointwiseOp,
    ZscoreOp,
)

__all__ = [
    "LinearStep",
    "PointwiseStep",
    "ZscoreStep",
    "ReduceStep",
    "PipelineProgram",
    "compose_weights",
    "composable",
    "build_program",
]


def compose_weights(W1: np.ndarray, op1, W2: np.ndarray, op2) -> np.ndarray:
    """Exact weights of ``stage2 ∘ stage1`` (both 'valid', stride-1).

    ``W1`` is (numel(op1), 1), ``W2`` (numel(op2), K); returns
    (numel(op1 ⊕ op2 − 1), K).  In absolute melt indices a valid row ``g``
    of stage 1 reads ``x[g + a1]``, so the chain reads
    ``x[g + a1 + a2]`` — the composite is the full N-D convolution of the
    operator tensors, and the ravel order matches the melt column order by
    construction.
    """
    op1 = tuple(int(k) for k in op1)
    op2 = tuple(int(k) for k in op2)
    K = W2.shape[1]
    k_out = tuple(a + b - 1 for a, b in zip(op1, op2))
    T1 = np.asarray(W1, np.float64).reshape(op1)
    T2 = np.asarray(W2, np.float64).reshape(op2 + (K,))
    out = np.zeros(k_out + (K,))
    for idx in np.ndindex(*op1):
        sl = tuple(slice(i, i + k) for i, k in zip(idx, op2))
        out[sl + (slice(None),)] += T1[idx] * T2
    return out.reshape(-1, K).astype(np.float32)


def composable(a: LinearOp, b: LinearOp) -> bool:
    """Whether stage ``b`` may merge into stage ``a``'s melt pass exactly."""
    unit = (1,) * len(a.op_shape)
    return (a.K == 1
            and a.padding == "valid" and b.padding == "valid"
            and a.stride == unit and b.stride == unit
            and a.dilation == unit and b.dilation == unit)


@dataclasses.dataclass
class LinearStep:
    """One fused linear group: a (possibly composed) bank over one grid."""

    grid: QuasiGrid
    weights: np.ndarray            # (numel, K) float32
    kind: str                      # 'stencil' (squeeze K) | 'bank' (keep K)
    factors: Optional[tuple]       # separable per-dim factors, or None
    fused_from: int                # how many graph ops merged into this pass

    @property
    def melt_calls(self) -> int:
        return self.grid.rank if self.factors is not None else 1


@dataclasses.dataclass
class PointwiseStep:
    fn: object


@dataclasses.dataclass
class ZscoreStep:
    grid: QuasiGrid
    window_col: np.ndarray         # normalized (numel,) window weights
    eps: float

    melt_calls = 1


@dataclasses.dataclass
class ReduceStep:
    kind: str                      # 'moments' | 'hist' | 'cov'
    order: int = 4
    bins: int = 0
    lo: float = 0.0
    hi: float = 0.0
    axis: object = None            # explicit spec (reduction-only graphs)


@dataclasses.dataclass
class PipelineProgram:
    """The planner's output: executable steps + the pass/melt accounting."""

    steps: Tuple
    passes: int                    # logical fused data traversals
    melt_calls: int                # exact melt() count on the materialize path
    out_shape: Tuple[int, ...]     # spatial shape after the last linear step
    channels: int                  # trailing channel extent (0 = none)
    out_kind: str                  # 'array' | 'moments' | 'hist' | 'cov'

    def describe(self) -> str:
        names = []
        for s in self.steps:
            if isinstance(s, LinearStep):
                tag = "x".join(map(str, s.grid.op_shape))
                sep = "sep" if s.factors is not None else "dense"
                names.append(f"linear[{tag},K={s.weights.shape[1]},{sep},"
                             f"fused={s.fused_from}]")
            elif isinstance(s, ZscoreStep):
                names.append("zscore")
            elif isinstance(s, PointwiseStep):
                names.append("pointwise")
            else:
                names.append(f"reduce[{s.kind}]")
        return (f"{' -> '.join(names)} | passes={self.passes} "
                f"melt_calls(materialize)={self.melt_calls}")


def _separable_ok(padding: str, pad_value, rank: int) -> bool:
    """Exactness gate for the per-dim rewrite inside a pipeline group."""
    if rank < 2:
        return False
    if padding == "valid":
        return True  # no fill is ever read
    return isinstance(pad_value, str) or pad_value == 0.0


def _plan_linear(op_shape, W, kind, cur_shape, stride, padding, dilation,
                 pad_value, fused_from, try_separable) -> LinearStep:
    from repro.core.engine import separable_factors  # deferred: cycle

    grid = make_quasi_grid(cur_shape, op_shape, stride, padding, dilation)
    factors = None
    unit = (1,) * grid.rank
    if (try_separable and stride == unit and dilation == unit
            and separable_profitable(op_shape)
            and _separable_ok(padding, pad_value, grid.rank)):
        factors = separable_factors(W, op_shape)
        if factors is not None:
            factors = tuple(factors)
    return LinearStep(grid=grid, weights=np.asarray(W, np.float32),
                      kind=kind, factors=factors, fused_from=fused_from)


def build_program(P: Pipe, opts: ExecOptions) -> PipelineProgram:
    """Fuse a pipe graph into the minimum-pass step program."""
    from repro.stats.local import window_weights_np  # deferred cycle

    steps = []
    cur_shape = P.spatial_shape
    channels = 0
    out_kind = "array"

    # gather ops; compose adjacent linear stages greedily left-to-right
    pending: Optional[LinearOp] = None
    pending_fused = 0

    def flush():
        nonlocal pending, pending_fused, cur_shape, channels
        if pending is None:
            return
        step = _plan_linear(
            pending.op_shape, pending.weights, pending.kind, cur_shape,
            pending.stride, pending.padding, pending.dilation,
            opts.pad_value, pending_fused,
            try_separable=(pending.kind == "bank" or pending_fused > 1))
        steps.append(step)
        cur_shape = step.grid.out_shape
        if pending.kind == "bank":
            channels = pending.K
        pending = None
        pending_fused = 0

    for op in P.ops:
        if isinstance(op, LinearOp):
            if pending is not None and composable(pending, op):
                comp = compose_weights(pending.weights, pending.op_shape,
                                       op.weights, op.op_shape)
                kind = "bank" if "bank" in (pending.kind, op.kind) \
                    else "stencil"
                merged = LinearOp(kind,
                                  tuple(a + b - 1 for a, b in
                                        zip(pending.op_shape, op.op_shape)),
                                  comp, 1, "valid", 1)
                pending_fused += 1
                pending = merged
            else:
                flush()
                pending = op
                pending_fused = 1
        elif isinstance(op, PointwiseOp):
            flush()
            steps.append(PointwiseStep(op.fn))
        elif isinstance(op, ZscoreOp):
            flush()
            grid = make_quasi_grid(cur_shape, op.window, 1, "same", 1)
            col = window_weights_np(op.window, op.wkind, op.sigma)
            steps.append(ZscoreStep(grid=grid, window_col=col, eps=op.eps))
        elif isinstance(op, MomentsOp):
            flush()
            if op.axis is not None and len(P.ops) > 1:
                raise ValueError(
                    "moments(axis=...) is only valid as a standalone "
                    "reduction (pipe(x).moments(axis=...)); multi-stage "
                    "pipelines reduce the spatial axes, keeping batch and "
                    "channel dims")
            steps.append(ReduceStep("moments", order=op.order,
                                    axis=op.axis))
            out_kind = "moments"
        elif isinstance(op, HistOp):
            flush()
            steps.append(ReduceStep("hist", bins=op.bins, lo=op.lo,
                                    hi=op.hi))
            out_kind = "hist"
        elif isinstance(op, CovOp):
            flush()
            steps.append(ReduceStep("cov"))
            out_kind = "cov"
        else:  # pragma: no cover — builder only produces the types above
            raise TypeError(f"unknown pipe op {op!r}")
    flush()

    traversals = sum(1 for s in steps
                     if isinstance(s, (LinearStep, ZscoreStep)))
    passes = max(traversals, 1 if steps else 0)
    melt_calls = sum(getattr(s, "melt_calls", 0) for s in steps)
    return PipelineProgram(
        steps=tuple(steps), passes=passes, melt_calls=melt_calls,
        out_shape=tuple(cur_shape), channels=channels, out_kind=out_kind)
