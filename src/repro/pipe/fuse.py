"""The melt-fusing planner: op chain → minimum-pass step program.

Three fusion rules (DESIGN.md §11):

1. **Weight composition** — adjacent linear stages merge into ONE
   operator-bank column when the rewrite is *exact*.  Every stage but the
   last must be single-column (K=1) and dilation-1; then

   - **'valid' chains compose for any strides**: in absolute melt indices
     a stride-``s1`` stage reads ``x[s1·g + a1]`` and a stride-``s2``
     successor reads stage-1 outputs at ``s2·h + a2``, so the chain reads
     ``x[(s1·s2)·h + (a1 + s1·a2)]`` — the composite is the *strided
     correlation* of the operator tensors (extent ``k1 + s1·(k2−1)`` per
     dim) at composite stride ``s1·s2``;
   - **stride-1 'same' chains split**: the output interior — positions
     whose every transitive read lands inside the input — is EXACTLY the
     composed-'valid' pass over the full input, placed at offset
     ``B = Σ pad_lo``; the thin boundary slabs that do read fill run the
     original per-stage program through the out-of-core tile machinery
     (pad at true volume edges + 'valid'), bit-identical to the unfused
     run.  The stitch is planned once (:class:`SplitStep`); when a slab
     cannot be planned (no interior, or reflect-pad wider than a slab)
     the chain falls back to per-stage passes.

   Composition is still *declined* for dilated stages, K>1 predecessors,
   and mixed 'same'/'valid' chains.  Composites accumulate in float64 and
   are cast to float32 once at plan time — a ≥3-stage chain never
   round-trips through float32 between merges.

2. **Trailing-reduction fusion** — a terminal ``moments``/``hist``/``cov``
   consumes the producing group's value inside the same executor: the
   intermediate is never re-melted (0 extra melt passes on the
   materialize path; never leaves the compiled computation on lax/fused).

3. **Separable rewrite** — each planned group's final weight matrix is
   re-examined with ``separable_factors``: bank-kind and composed groups
   whose columns are rank-1 outer products run as per-dim 1-D passes past
   the ``separable_profitable`` crossover ('same' needs a zero/mode fill;
   'valid' is unconditionally exact, strided included — each 1-D pass
   carries its own dim's stride).  Plain ``.stencil``/``.gaussian``
   stages stay dense for parity with ``apply_stencil``.

The program records ``passes`` (logical fused traversals; a split counts
as one) and ``melt_calls`` (the exact ``melt()`` count the materialize
path pays: separable groups pay one 1-D melt per dim, a split pays its
interior plus every boundary slab's per-stage replay) — the numbers the
no-extra-melt tests assert against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.grid import (
    QuasiGrid,
    chain_same_margins,
    compose_footprints,
    make_quasi_grid,
)
from repro.core.plan import ExecOptions, separable_profitable
from repro.pipe.graph import (
    CovOp,
    HistOp,
    LinearOp,
    MomentsOp,
    Pipe,
    PointwiseOp,
    ZscoreOp,
)

__all__ = [
    "LinearStep",
    "PointwiseStep",
    "ZscoreStep",
    "ReduceStep",
    "SplitStep",
    "PipelineProgram",
    "compose_weights",
    "composable",
    "build_program",
]


def compose_weights(W1: np.ndarray, op1, W2: np.ndarray, op2,
                    stride1=None) -> np.ndarray:
    """Exact weights of ``stage2 ∘ stage1`` (both 'valid'), in float64.

    ``W1`` is (numel(op1), 1), ``W2`` (numel(op2), K); returns the
    (numel(op1 ⊕ op2), K) float64 composite — callers cast to float32
    exactly once when the whole chain is folded, so multi-stage merges
    never quantize intermediates.  In absolute melt indices a valid row
    ``g`` of stage 1 reads ``x[s1·g + a1]``; a successor tap ``a2`` reads
    stage-1 output ``g + a2`` — i.e. ``x[s1·g + (a1 + s1·a2)]`` — so the
    composite tap set is ``{a1 + s1·a2}`` with weights ``w1[a1]·w2[a2]``
    (extent ``k1 + s1·(k2−1)`` per dim; ``stride1=None`` means unit, the
    plain full N-D convolution), and the ravel order matches the melt
    column order by construction.
    """
    op1 = tuple(int(k) for k in op1)
    op2 = tuple(int(k) for k in op2)
    s1 = ((1,) * len(op1) if stride1 is None
          else tuple(int(v) for v in stride1))
    K = W2.shape[1]
    k_out = tuple(a + s * (b - 1) for a, b, s in zip(op1, op2, s1))
    T1 = np.asarray(W1, np.float64).reshape(op1)
    T2 = np.asarray(W2, np.float64).reshape(op2 + (K,))
    out = np.zeros(k_out + (K,))
    for idx in np.ndindex(*op2):
        sl = tuple(slice(s * i, s * i + k)
                   for i, k, s in zip(idx, op1, s1))
        out[sl + (slice(None),)] += T2[idx] * T1[..., None]
    return out.reshape(-1, K)


def composable(a: LinearOp, b: LinearOp) -> bool:
    """Whether stage ``b`` may join stage ``a``'s fused melt pass exactly.

    'valid'→'valid' composes for any strides (strided correlation);
    'same'→'same' requires unit strides (the interior/boundary split's
    offset algebra).  Dilation and K>1 predecessors always decline.
    """
    unit = (1,) * len(a.op_shape)
    if a.K != 1 or a.dilation != unit or b.dilation != unit:
        return False
    if a.padding == "valid" and b.padding == "valid":
        return True
    return (a.padding == "same" and b.padding == "same"
            and a.stride == unit and b.stride == unit)


@dataclasses.dataclass
class LinearStep:
    """One fused linear group: a (possibly composed) bank over one grid."""

    grid: QuasiGrid
    weights: np.ndarray            # (numel, K) float32
    kind: str                      # 'stencil' (squeeze K) | 'bank' (keep K)
    factors: Optional[tuple]       # separable per-dim factors, or None
    fused_from: int                # how many graph ops merged into this pass

    @property
    def melt_calls(self) -> int:
        return self.grid.rank if self.factors is not None else 1


@dataclasses.dataclass
class PointwiseStep:
    fn: object


@dataclasses.dataclass
class ZscoreStep:
    grid: QuasiGrid
    window_col: np.ndarray         # normalized (numel,) window weights
    eps: float

    melt_calls = 1


@dataclasses.dataclass
class ReduceStep:
    kind: str                      # 'moments' | 'hist' | 'cov'
    order: int = 4
    bins: int = 0
    lo: float = 0.0
    hi: float = 0.0
    axis: object = None            # explicit spec (reduction-only graphs)


@dataclasses.dataclass
class SplitStep:
    """A stride-1 'same' chain planned as interior ∘ boundary (rule 1b).

    ``interior`` is the composed-'valid' group over the FULL input — its
    output is the 'same' chain's output on ``[B, n−C)`` per dim (``B``/
    ``C`` the accumulated pad margins, ``interior_lo = B``).  Each
    boundary slab replays ``inner`` (the original per-stage program)
    through the tile machinery's pad-at-true-edge + 'valid' schedule
    (``specs``), bit-identical to the unfused run where fill is read.
    One logical traversal; the materialize path pays the interior's
    melts plus every slab's per-stage replay.
    """

    interior: LinearStep
    inner: "PipelineProgram"       # the unfused per-stage chain
    specs: Tuple                   # one TileSpec per boundary slab
    interior_lo: Tuple[int, ...]   # B: interior offset on the output grid
    out_shape: Tuple[int, ...]
    kind: str                      # 'stencil' | 'bank'
    fused_from: int

    @property
    def melt_calls(self) -> int:
        return (self.interior.melt_calls
                + len(self.specs) * self.inner.melt_calls)


@dataclasses.dataclass
class PipelineProgram:
    """The planner's output: executable steps + the pass/melt accounting."""

    steps: Tuple
    passes: int                    # logical fused data traversals
    melt_calls: int                # exact melt() count on the materialize path
    out_shape: Tuple[int, ...]     # spatial shape after the last linear step
    channels: int                  # trailing channel extent (0 = none)
    out_kind: str                  # 'array' | 'moments' | 'hist' | 'cov'

    def describe(self) -> str:
        names = []
        for s in self.steps:
            if isinstance(s, LinearStep):
                tag = "x".join(map(str, s.grid.op_shape))
                sep = "sep" if s.factors is not None else "dense"
                names.append(f"linear[{tag},K={s.weights.shape[1]},{sep},"
                             f"fused={s.fused_from}]")
            elif isinstance(s, SplitStep):
                tag = "x".join(map(str, s.interior.grid.op_shape))
                names.append(f"split[{tag},K={s.interior.weights.shape[1]},"
                             f"slabs={len(s.specs)},fused={s.fused_from}]")
            elif isinstance(s, ZscoreStep):
                names.append("zscore")
            elif isinstance(s, PointwiseStep):
                names.append("pointwise")
            else:
                names.append(f"reduce[{s.kind}]")
        return (f"{' -> '.join(names)} | passes={self.passes} "
                f"melt_calls(materialize)={self.melt_calls}")


def _separable_ok(padding: str, pad_value, rank: int) -> bool:
    """Exactness gate for the per-dim rewrite inside a pipeline group."""
    if rank < 2:
        return False
    if padding == "valid":
        return True  # no fill is ever read
    return isinstance(pad_value, str) or pad_value == 0.0


def _plan_linear(op_shape, W, kind, cur_shape, stride, padding, dilation,
                 pad_value, fused_from, try_separable) -> LinearStep:
    from repro.core.engine import separable_factors  # deferred: cycle

    grid = make_quasi_grid(cur_shape, op_shape, stride, padding, dilation)
    factors = None
    unit = (1,) * grid.rank
    # quantize the (possibly float64-folded) bank exactly once, here;
    # factors derive from the quantized operator so they stay float32
    W32 = np.asarray(W, np.float32)
    # strided 'valid' grids stay separable-eligible: each 1-D pass carries
    # its own dim's stride, which is exact when no fill is ever read
    if (try_separable and grid.dilation == unit
            and (grid.stride == unit or padding == "valid")
            and separable_profitable(op_shape)
            and _separable_ok(padding, pad_value, grid.rank)):
        factors = separable_factors(W32, op_shape)
        if factors is not None:
            factors = tuple(factors)
    return LinearStep(grid=grid, weights=W32, kind=kind, factors=factors,
                      fused_from=fused_from)


def _compose_chain(chain) -> Tuple[np.ndarray, tuple, tuple]:
    """Fold a 'valid' chain's operator tensors left-to-right in float64.

    Returns ``(weights, op_shape, stride)`` of the composite: pairwise
    strided correlation with the *accumulated* predecessor stride, so the
    running composite after k stages has extent ``Σ (Π_{j<i} s_j)·(k_i−1)
    + 1`` and stride ``Π s_i`` per dim.
    """
    op = chain[0]
    W = np.asarray(op.weights, np.float64)
    shape = op.op_shape
    stride = tuple(op.stride)
    for nxt in chain[1:]:
        W = compose_weights(W, shape, nxt.weights, nxt.op_shape,
                            stride1=stride)
        shape = tuple(k1 + s * (k2 - 1)
                      for k1, k2, s in zip(shape, nxt.op_shape, stride))
        stride = tuple(s * t for s, t in zip(stride, nxt.stride))
    return W, shape, stride


def _boundary_boxes(shape, lo_m, hi_m):
    """Onion decomposition of the interior's complement into 2·rank
    disjoint slabs: slab ``d`` spans the lo/hi margin along dim ``d``,
    the *interior* range on dims < d, and the full extent on dims > d —
    together with the interior box they tile the output exactly once."""
    rank = len(shape)
    boxes = []
    for d in range(rank):
        base_lo = [lo_m[i] if i < d else 0 for i in range(rank)]
        base_hi = [shape[i] - hi_m[i] if i < d else shape[i]
                   for i in range(rank)]
        if lo_m[d] > 0:
            lo, hi = list(base_lo), list(base_hi)
            lo[d], hi[d] = 0, lo_m[d]
            boxes.append((tuple(lo), tuple(hi)))
        if hi_m[d] > 0:
            lo, hi = list(base_lo), list(base_hi)
            lo[d], hi[d] = shape[d] - hi_m[d], shape[d]
            boxes.append((tuple(lo), tuple(hi)))
    return boxes


def _plan_same_split(chain, cur_shape, opts) -> Optional[SplitStep]:
    """Plan a stride-1 'same' chain as interior ∘ boundary, or ``None``
    when the split cannot be planned (no interior survives the margins,
    or a slab is too thin for this pad mode)."""
    from repro.pipe import tiled  # deferred: tiled imports this module

    rank = len(cur_shape)
    kind = "bank" if chain[-1].kind == "bank" else "stencil"
    K = chain[-1].K
    # the unfused per-stage steps — exactly what the declined-composition
    # plan would run; the boundary slabs replay them bit-identically
    inner_steps = []
    shp = tuple(cur_shape)
    for op in chain:
        st = _plan_linear(op.op_shape, op.weights, op.kind, shp,
                          op.stride, op.padding, op.dilation,
                          opts.pad_value, 1,
                          try_separable=(op.kind == "bank"))
        inner_steps.append(st)
        shp = st.grid.out_shape
    inner = PipelineProgram(
        steps=tuple(inner_steps), passes=len(inner_steps),
        melt_calls=sum(s.melt_calls for s in inner_steps),
        out_shape=tuple(shp), channels=(K if kind == "bank" else 0),
        out_kind="array")
    B, C = chain_same_margins([s.grid for s in inner_steps])
    if any(n - b - c < 1 for n, b, c in zip(cur_shape, B, C)):
        return None  # the whole output is boundary: keep per-stage passes
    W, comp_shape, _ = _compose_chain(chain)
    interior = _plan_linear(comp_shape, W, kind, cur_shape, (1,) * rank,
                            "valid", (1,) * rank, opts.pad_value,
                            len(chain), try_separable=True)
    geoms = tiled._linear_geoms(inner)
    footprint = (compose_footprints([s.grid for s in geoms])
                 or ((1, 0, 0),) * rank)
    specs = []
    try:
        for lo, hi in _boundary_boxes(cur_shape, B, C):
            specs.append(tiled._tile_spec(geoms, footprint, lo, hi,
                                          tuple(cur_shape), opts.pad_value))
    except ValueError:
        return None  # slab too thin for this pad mode (e.g. wide reflect)
    return SplitStep(interior=interior, inner=inner, specs=tuple(specs),
                     interior_lo=tuple(B), out_shape=tuple(cur_shape),
                     kind=kind, fused_from=len(chain))


def build_program(P: Pipe, opts: ExecOptions,
                  split_same: bool = True) -> PipelineProgram:
    """Fuse a pipe graph into the minimum-pass step program.

    ``split_same=False`` pins 'same' chains to per-stage passes (no
    :class:`SplitStep`) — the out-of-core and sharded front ends route
    per stage themselves, and their tile/slab machinery already provides
    the pad-at-true-edge execution the split would nest inside it.
    """
    from repro.stats.local import window_weights_np  # deferred cycle

    steps = []
    cur_shape = P.spatial_shape
    channels = 0
    out_kind = "array"

    # gather ops; accumulate the longest composable linear chain, then
    # plan it as one group in flush() (composites fold in float64 there —
    # never through a per-merge float32 round-trip)
    pending: list = []

    def flush():
        nonlocal pending, cur_shape, channels
        if not pending:
            return
        chain, pending = pending, []
        if len(chain) == 1:
            op = chain[0]
            step = _plan_linear(
                op.op_shape, op.weights, op.kind, cur_shape, op.stride,
                op.padding, op.dilation, opts.pad_value, 1,
                try_separable=(op.kind == "bank"))
            steps.append(step)
            cur_shape = step.grid.out_shape
        elif chain[0].padding == "valid":
            W, comp_shape, comp_stride = _compose_chain(chain)
            kind = "bank" if chain[-1].kind == "bank" else "stencil"
            step = _plan_linear(
                comp_shape, W, kind, cur_shape, comp_stride, "valid",
                (1,) * len(comp_shape), opts.pad_value, len(chain),
                try_separable=True)
            steps.append(step)
            cur_shape = step.grid.out_shape
        else:  # stride-1 'same' chain: interior/boundary split
            split = (_plan_same_split(chain, cur_shape, opts)
                     if split_same else None)
            if split is not None:
                steps.append(split)
                cur_shape = split.out_shape
            else:
                for op in chain:
                    step = _plan_linear(
                        op.op_shape, op.weights, op.kind, cur_shape,
                        op.stride, op.padding, op.dilation,
                        opts.pad_value, 1,
                        try_separable=(op.kind == "bank"))
                    steps.append(step)
                    cur_shape = step.grid.out_shape
        if chain[-1].kind == "bank":
            channels = chain[-1].K

    for op in P.ops:
        if isinstance(op, LinearOp):
            if pending and not composable(pending[-1], op):
                flush()
            pending.append(op)
        elif isinstance(op, PointwiseOp):
            flush()
            steps.append(PointwiseStep(op.fn))
        elif isinstance(op, ZscoreOp):
            flush()
            grid = make_quasi_grid(cur_shape, op.window, 1, "same", 1)
            col = window_weights_np(op.window, op.wkind, op.sigma)
            steps.append(ZscoreStep(grid=grid, window_col=col, eps=op.eps))
        elif isinstance(op, MomentsOp):
            flush()
            if op.axis is not None and len(P.ops) > 1:
                raise ValueError(
                    "moments(axis=...) is only valid as a standalone "
                    "reduction (pipe(x).moments(axis=...)); multi-stage "
                    "pipelines reduce the spatial axes, keeping batch and "
                    "channel dims")
            steps.append(ReduceStep("moments", order=op.order,
                                    axis=op.axis))
            out_kind = "moments"
        elif isinstance(op, HistOp):
            flush()
            steps.append(ReduceStep("hist", bins=op.bins, lo=op.lo,
                                    hi=op.hi))
            out_kind = "hist"
        elif isinstance(op, CovOp):
            flush()
            steps.append(ReduceStep("cov"))
            out_kind = "cov"
        else:  # pragma: no cover — builder only produces the types above
            raise TypeError(f"unknown pipe op {op!r}")
    flush()

    traversals = sum(1 for s in steps
                     if isinstance(s, (LinearStep, ZscoreStep, SplitStep)))
    passes = max(traversals, 1 if steps else 0)
    melt_calls = sum(getattr(s, "melt_calls", 0) for s in steps)
    return PipelineProgram(
        steps=tuple(steps), passes=passes, melt_calls=melt_calls,
        out_shape=tuple(cur_shape), channels=channels, out_kind=out_kind)
