"""``repro.serve`` — async batched analytics serving for pipe programs.

The request-level tier above the plan cache (DESIGN.md §15): a
:class:`PipeService` accepts compiled-pipe requests from many callers,
coalesces same-plan-key requests into one ``pipe.batched`` dispatch
(:mod:`repro.serve.coalesce`), admits work plan-cache-aware so cold-plan
stampedes cannot serialize the worker pool
(:mod:`repro.serve.admission`), and sheds load with per-tenant fairness
when the bounded queue fills (:mod:`repro.serve.backpressure`).  A
seeded open-loop load generator (:mod:`repro.serve.loadgen`) drives the
whole stack and reports latency percentiles.

Quickstart::

    from repro.serve import PipeService, ServeConfig
    from repro.pipe import pipe

    svc = PipeService(ServeConfig(max_batch=8, max_wait_ms=2.0))
    svc.warmup(pipe(x).gaussian(1.5).gradient())
    t = svc.submit(pipe(x).gaussian(1.5).gradient(), tenant="alice")
    y = t.result()        # == pipe(x).gaussian(1.5).gradient().run()
    svc.close()           # drains in-flight work first

High-rate callers should register the program once and submit data —
per-request graph construction on the caller thread otherwise caps
aggregate throughput::

    prog = svc.register(pipe(x0).gaussian(1.5).gradient())
    tickets = [prog.submit(x) for x in xs]   # data only, key cached
"""
from repro.serve.admission import (AdmissionController, ColdPlanOverload,
                                   MemoryBudget)
from repro.serve.backpressure import FairQueue, ShedError
from repro.serve.coalesce import Coalescer, Request, execute_batch
from repro.serve.loadgen import run_load
from repro.serve.service import (PipeService, Program, ServeConfig,
                                 ServiceClosed, Ticket)

__all__ = [
    "PipeService",
    "Program",
    "ServeConfig",
    "Ticket",
    "ServiceClosed",
    "Coalescer",
    "Request",
    "execute_batch",
    "AdmissionController",
    "ColdPlanOverload",
    "MemoryBudget",
    "FairQueue",
    "ShedError",
    "run_load",
]
