"""Bounded admission queue: load shedding + per-tenant fairness.

The service's front door is a :class:`FairQueue` — one FIFO lane per
tenant, drained round-robin, with a global depth bound and an optional
per-tenant quota.  The structure is **not** thread-safe by design: it is
owned by the service's event-loop thread and every mutation happens
there (``call_soon_threadsafe`` is the only door in), which keeps the
shed/fairness logic deterministic enough to unit-test with plain calls.

Shedding policy, applied only when the *global* bound is hit:

- ``"reject-new"`` — the arriving request is shed (:class:`ShedError`).
- ``"shed-largest"`` — the *newest* request of the tenant with the
  deepest backlog is displaced to make room (the arriving tenant's own
  lane counts too, so a lone flooding tenant always sheds itself).
  ``put`` returns the displaced item for the caller to fail.

A tenant over its own quota is always a ``reject-new`` regardless of
policy: the quota is the fairness contract — one tenant's burst must
never displace another tenant's queued work.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Optional

__all__ = ["ShedError", "FairQueue", "POLICIES"]

POLICIES = ("reject-new", "shed-largest")


class ShedError(RuntimeError):
    """A request was dropped by backpressure; ``reason`` says why
    (``"queue-full"`` or ``"tenant-quota"``)."""

    def __init__(self, msg: str, reason: str):
        super().__init__(msg)
        self.reason = reason


class FairQueue:
    """Per-tenant FIFO lanes drained round-robin (loop-owned, unlocked)."""

    def __init__(self, depth: int, tenant_quota: Optional[int] = None,
                 policy: str = "reject-new"):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        if tenant_quota is not None and tenant_quota < 1:
            raise ValueError(f"tenant_quota must be >= 1, got "
                             f"{tenant_quota}")
        if policy not in POLICIES:
            raise ValueError(f"unknown shed policy {policy!r}; expected "
                             f"one of {POLICIES}")
        self.depth = int(depth)
        self.tenant_quota = tenant_quota
        self.policy = policy
        #: insertion-ordered so round-robin order is deterministic
        self._lanes: "OrderedDict[str, deque]" = OrderedDict()
        self._len = 0
        #: round-robin cursor: index into the lane key order
        self._rr = 0

    def __len__(self) -> int:
        return self._len

    def depths(self) -> dict:
        """Per-tenant queued counts (observability)."""
        return {t: len(q) for t, q in self._lanes.items() if q}

    def put(self, item, tenant: str):
        """Enqueue; returns a displaced item under ``shed-largest`` (the
        caller fails its future), else ``None``.  Raises
        :class:`ShedError` when the request itself is shed."""
        lane = self._lanes.get(tenant)
        if (self.tenant_quota is not None and lane is not None
                and len(lane) >= self.tenant_quota):
            raise ShedError(
                f"tenant {tenant!r} is over its quota of "
                f"{self.tenant_quota} queued requests", "tenant-quota")
        displaced = None
        if self._len >= self.depth:
            if self.policy == "reject-new":
                raise ShedError(
                    f"queue full ({self.depth} requests)", "queue-full")
            # shed-largest: displace the newest item of the deepest lane
            # (ties break toward the arriving tenant so a flooder pays
            # before anyone else does)
            deepest = max(
                (t for t, q in self._lanes.items() if q),
                key=lambda t: (len(self._lanes[t]), t == tenant))
            displaced = self._lanes[deepest].pop()
            self._len -= 1
        if lane is None:
            lane = self._lanes[tenant] = deque()
        lane.append(item)
        self._len += 1
        return displaced

    def get(self):
        """``(item, tenant)`` in round-robin tenant order; raises
        ``IndexError`` when empty."""
        if not self._len:
            raise IndexError("get from an empty FairQueue")
        keys = list(self._lanes)
        for off in range(len(keys)):
            t = keys[(self._rr + off) % len(keys)]
            q = self._lanes[t]
            if q:
                self._rr = (self._rr + off + 1) % len(keys)
                self._len -= 1
                return q.popleft(), t
        raise AssertionError("length/lane bookkeeping desynced")

    def putback(self, item, tenant: str) -> None:
        """Return an item to the *front* of its lane (an admission
        "wait" verdict re-queues without losing FIFO position); never
        sheds — the item was already admitted once."""
        self._lanes.setdefault(tenant, deque()).appendleft(item)
        self._len += 1

    def drain(self):
        """Pop everything (shutdown): ``[(item, tenant), ...]``."""
        out = []
        while self._len:
            out.append(self.get())
        return out
