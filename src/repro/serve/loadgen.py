"""Seeded multi-tenant open-loop load generator + latency report.

Open-loop means arrivals come from a fixed schedule (exponential
inter-arrivals at ``rate`` req/s from a seeded generator), not from
completions — the canonical way to measure a service's latency under
load, because a closed loop self-throttles exactly when the service
degrades (coordinated omission).  Tenants round-robin over the
arrival sequence; the plan-key mix is the adversarial knob:

- ``"same"``   — every request shares one plan key (best case: windows
  fill to ``max_batch``);
- ``"mixed"``  — ``distinct`` different keys interleaved (windows fill
  slower; coalescing still wins within each key);
- ``"churn"``  — every request a fresh plan key (worst case: nothing
  coalesces and the plan cache takes a compile per request — this is
  what the admission controller's cold cap is for).

The report is one JSON-able dict: counts (served / shed / failed),
throughput, latency percentiles, coalesce ratio, per-tenant totals,
and a ``verified`` block — ``verify`` sampled requests are re-run
directly through ``Pipe.run`` and compared **bit-identically** (the
generator's graphs are array-valued, where the serving tier's equality
contract is exact).

CLI::

    PYTHONPATH=src python -m repro.serve.loadgen --smoke

exits nonzero if verification fails or if any request was shed below
the shedding threshold (requests ≤ queue capacity must never drop —
the zero-drop guarantee the bench gate also asserts).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

import numpy as np

from repro.pipe.graph import pipe
from repro.serve.backpressure import ShedError
from repro.serve.service import PipeService, ServeConfig

__all__ = ["run_load", "main"]

MIXES = ("same", "mixed", "churn")


def _graph(x, sigma: float):
    """The generator's workload: smooth → all first partials (array
    output, multi-stage, so it interns one PipePlan per sigma)."""
    return pipe(x).gaussian(sigma, op_shape=5).gradient()


def run_load(service: Optional[PipeService] = None, *, n: int = 64,
             rate: float = 2000.0, tenants: int = 2, mix: str = "same",
             distinct: int = 4, shape=(32, 32), seed: int = 0,
             verify: int = 8, warm: bool = True,
             config: Optional[ServeConfig] = None) -> dict:
    """Drive ``n`` requests through a service and report.

    Owns the service lifecycle when ``service=None`` (builds one from
    ``config``, drains and closes it at the end); a caller-provided
    service is left open.  Deterministic for a fixed seed up to
    scheduling: the arrival schedule, input arrays and key mix all come
    from ``np.random.default_rng(seed)``.
    """
    if mix not in MIXES:
        raise ValueError(f"unknown mix {mix!r}; expected one of {MIXES}")
    if n < 1:
        raise ValueError(f"need n >= 1 requests, got {n}")
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n,) + tuple(shape)).astype(np.float32)
    gaps = rng.exponential(1.0 / rate, size=n)
    if mix == "same":
        sigmas = np.full(n, 1.5)
    elif mix == "mixed":
        sigmas = 1.0 + 0.25 * rng.integers(0, distinct, size=n)
    else:  # churn: a fresh plan key per request
        sigmas = 1.0 + 0.01 * np.arange(1, n + 1)

    own = service is None
    svc = service if service is not None else PipeService(config)
    try:
        if warm and mix != "churn":
            for s in sorted(set(float(v) for v in sigmas)):
                svc.warmup(_graph(xs[0], s))
        t0 = time.monotonic()
        due = t0
        tickets = []
        for i in range(n):
            due += gaps[i]
            pause = due - time.monotonic()
            if pause > 0:
                time.sleep(pause)  # open loop: fixed schedule
            tickets.append(svc.submit(
                _graph(xs[i], float(sigmas[i])),
                tenant=f"tenant-{i % max(1, tenants)}"))
        served, shed, failed = [], 0, 0
        per_tenant: dict = {}
        for i, t in enumerate(tickets):
            exc = t.exception()
            bucket = per_tenant.setdefault(t.tenant,
                                           {"served": 0, "dropped": 0})
            if exc is None:
                served.append(i)
                bucket["served"] += 1
            else:
                bucket["dropped"] += 1
                if isinstance(exc, ShedError):
                    shed += 1
                else:
                    failed += 1
        elapsed = time.monotonic() - t0

        lat = np.array([tickets[i].latency for i in served], np.float64)
        pct = (lambda q: float(np.percentile(lat * 1e3, q))
               if len(lat) else float("nan"))
        stats = svc.stats()

        verified = ok = 0
        if verify and served:
            for i in rng.choice(served, size=min(verify, len(served)),
                                replace=False):
                want = np.asarray(_graph(xs[i], float(sigmas[i])).run())
                got = np.asarray(tickets[i].result())
                verified += 1
                ok += int(np.array_equal(want, got))
        return {
            "n": n, "mix": mix, "rate_rps": rate, "tenants": tenants,
            "seed": seed,
            "served": len(served), "shed": shed, "failed": failed,
            "elapsed_s": round(elapsed, 4),
            "throughput_rps": round(len(served) / max(elapsed, 1e-9), 1),
            "latency_ms": {"p50": round(pct(50), 3),
                           "p90": round(pct(90), 3),
                           "p99": round(pct(99), 3)},
            "queue_capacity": svc.config.queue_depth,
            "warm_keys": stats.get("warm_keys", 0),
            "per_tenant": per_tenant,
            "verified": verified, "verify_ok": ok,
        }
    finally:
        if own:
            svc.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small deterministic run with hard assertions "
                    "(CI): zero sheds below capacity + bit-identical "
                    "verification")
    ap.add_argument("-n", type=int, default=64)
    ap.add_argument("--rate", type=float, default=2000.0)
    ap.add_argument("--mix", choices=MIXES, default="same")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=8)
    args = ap.parse_args(argv)

    n = 32 if args.smoke else args.n
    report = run_load(n=n, rate=args.rate, mix=args.mix,
                      tenants=args.tenants, seed=args.seed,
                      verify=args.verify,
                      config=ServeConfig(queue_depth=max(256, n)))
    print(json.dumps(report, indent=2))
    failures = []
    if report["verified"] and report["verify_ok"] != report["verified"]:
        failures.append(f"verification: {report['verify_ok']}/"
                        f"{report['verified']} bit-identical")
    if report["n"] <= report["queue_capacity"] and report["shed"]:
        failures.append(f"{report['shed']} requests shed below the "
                        f"shedding threshold (capacity "
                        f"{report['queue_capacity']})")
    if report["failed"]:
        failures.append(f"{report['failed']} requests failed")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
