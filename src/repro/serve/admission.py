"""Plan-cache-aware admission control + the shared memory budget.

Two gates stand between a closed batch and a worker (DESIGN.md §15):

**Cold-plan admission.**  A batch whose executor is already compiled
("warm") dispatches immediately — the plan cache serves it without
tracing.  A *cold* batch costs a jit trace (tens of ms to seconds),
so an unbounded stampede of distinct cold keys would serialize the
whole worker pool behind the compiler.  The
:class:`AdmissionController` caps concurrent cold builds at
``max_cold``; same-key duplicates always ``"wait"`` (the plan cache's
per-key build latch means the second caller would block on the first
anyway), and over-cap distinct keys either ``"wait"`` (default) or
``"reject"`` with :class:`ColdPlanOverload`.  Warmth is learned from
releases and probed from the plan cache itself
(:func:`repro.core.plan.plan_cached`), so a service restart against a
warm process doesn't re-ramp.

**Memory budget.**  Tiled requests hold a byte reservation sized by
:meth:`TiledProgram.working_set_bytes
<repro.pipe.tiled.TiledProgram.working_set_bytes>` for their whole
stream, arbitrated by :class:`MemoryBudget` — a condition-variable
byte semaphore, so concurrent out-of-core streams queue instead of
collectively overshooting the host.  An oversized request (reservation
larger than the whole budget) admits only when it would run alone —
best effort beats deadlock.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from repro.core.plan import plan_cached

__all__ = ["ColdPlanOverload", "AdmissionController", "MemoryBudget"]


class ColdPlanOverload(RuntimeError):
    """Rejected: too many distinct cold plans compiling at once."""


class AdmissionController:
    """Caps concurrent cold-plan builds (loop-owned, unlocked).

    Keys are opaque hashables; the service keys on ``(plan key, batch
    size)`` because each distinct batch size traces its own stacked
    executor.  ``cache_key`` (optional) is the key the dispatch interns
    under in the process plan cache — when present and resident, the
    batch is warm regardless of what this controller has seen.
    """

    def __init__(self, max_cold: int = 2, policy: str = "queue"):
        if max_cold < 1:
            raise ValueError(f"max_cold must be >= 1, got {max_cold}")
        if policy not in ("queue", "reject"):
            raise ValueError(f"unknown cold policy {policy!r}; expected "
                             f"'queue' or 'reject'")
        self.max_cold = int(max_cold)
        self.policy = policy
        self._warm = set()
        self._building = set()

    def is_warm(self, key, cache_key: Optional[tuple] = None) -> bool:
        return (key in self._warm
                or (cache_key is not None and plan_cached(cache_key)))

    def try_acquire(self, key, cache_key: Optional[tuple] = None) -> str:
        """``"run"`` (dispatch now — a cold grant holds a build slot
        until :meth:`release`), ``"wait"`` (park; a release will re-pump)
        or ``"reject"`` (fail with :class:`ColdPlanOverload`)."""
        if self.is_warm(key, cache_key):
            return "run"
        if key in self._building:
            # a worker is already tracing this exact key: the plan
            # cache's build latch would block a second worker for
            # nothing — park until the first release marks it warm
            return "wait"
        if len(self._building) < self.max_cold:
            self._building.add(key)
            return "run"
        return "wait" if self.policy == "queue" else "reject"

    def release(self, key) -> None:
        """The dispatch finished (either way): the key is warm now —
        even a failed run leaves the traced executor interned."""
        self._building.discard(key)
        self._warm.add(key)

    def warm_keys(self) -> int:
        return len(self._warm)


class MemoryBudget:
    """A byte semaphore arbitrating concurrent working sets.

    Thread-safe (reservations are taken on worker threads).  ``reserve``
    blocks until the bytes fit; reservations larger than the whole
    budget admit only when nothing else holds (running alone is the
    best a too-big request can get — refusing forever would turn a
    sizing estimate into a deadlock).
    """

    def __init__(self, total: int):
        if total < 1:
            raise ValueError(f"budget must be >= 1 byte, got {total}")
        self.total = int(total)
        self.in_use = 0
        self.peak = 0
        self.waits = 0
        self._cv = threading.Condition()

    @contextlib.contextmanager
    def reserve(self, nbytes: int, timeout: Optional[float] = None):
        nbytes = int(nbytes)
        if nbytes < 0:
            raise ValueError(f"cannot reserve {nbytes} bytes")
        with self._cv:
            def fits():
                return (self.in_use + nbytes <= self.total
                        or (nbytes > self.total and self.in_use == 0))
            if not fits():
                self.waits += 1
                if not self._cv.wait_for(fits, timeout=timeout):
                    raise TimeoutError(
                        f"memory budget: {nbytes} bytes not available "
                        f"within {timeout}s ({self.in_use}/{self.total} "
                        f"in use)")
            self.in_use += nbytes
            self.peak = max(self.peak, self.in_use)
        try:
            yield
        finally:
            with self._cv:
                self.in_use -= nbytes
                self._cv.notify_all()
