"""Request coalescing: same-plan-key requests stack into one dispatch.

The throughput lever of the serving tier (DESIGN.md §15): small-tile
pipe programs are dispatch-bound, and PR 1 measured a single
``pipe.batched`` call at B=8 running 3–6× faster than 8 sequential
runs.  The :class:`Coalescer` holds an *open window* per plan key
(:func:`repro.pipe.compile.plan_key_for` — equal keys guarantee equal
shape, dtype, options, and graph, so stacking is always legal); a
window closes into a :class:`Batch` when it reaches ``max_batch`` or
its ``max_wait`` deadline expires, whichever comes first.

Unstacking (:func:`execute_batch`) depends on the graph's terminal:

- **array outputs** run the stacked input through the batched graph and
  slice ``out[i]`` — *bit-identical* to the per-request run on both the
  lax and materialize paths (the vmapped melt touches each item's values
  in the same order as the unbatched one);
- **moments** run batched natively (the reduction is per batch item by
  contract) and slice the state leaves — equal to the direct run only
  to float tolerance: the batched reduction folds chunks in a different
  order, and the chunked-centered merge is not bitwise associative;
- **hist / cov** reduce over *all* elements under ``batched`` (one
  merged state), so the terminal is split off: the producer prefix runs
  batched, and the terminal (:func:`~repro.stats.hist.histogram_fixed`
  / :func:`~repro.stats.cov.channel_cov`) is ``vmap``-ed over the
  stacked producer output, then sliced per request.

Requests that cannot coalesce — already-batched graphs, tiled runs
(``tiles=``/``memory_budget=``), empty-window shapes — form solo
batches and flow through the same dispatch path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ExecOptions, plan_cached
from repro.pipe import compile as _compile
from repro.pipe.graph import CovOp, HistOp, Pipe

__all__ = ["Request", "Batch", "Coalescer", "coalescible", "begin_batch",
           "execute_batch", "batch_cache_key"]


@dataclasses.dataclass(eq=False)
class Request:
    """One submitted pipeline run, and where its answer goes.

    Identity-compared (``eq=False``): requests live in deques the
    service removes from by identity, and value equality over array
    fields is both meaningless and ambiguous."""

    id: int
    pipe: Pipe
    method: str
    pad_value: object
    out_dtype: object
    tiles: object
    memory_budget: Optional[int]
    tenant: str
    future: object  # concurrent.futures.Future
    t_submit: float
    #: grouping key — equal keys may stack (``None`` = never coalesce)
    key: Optional[tuple]
    #: wall-clock seconds from submit to resolution (set at completion)
    latency: Optional[float] = None

    @property
    def coalescible(self) -> bool:
        return self.key is not None


@dataclasses.dataclass(eq=False)
class Batch:
    """A closed window: requests guaranteed mutually stackable.
    Identity-compared, same as :class:`Request`."""

    key: Optional[tuple]
    requests: List[Request]

    def __len__(self) -> int:
        return len(self.requests)


def coalescible(P: Pipe, tiles=None, memory_budget=None) -> bool:
    """Whether a request may share a batch: unbatched graph, concrete
    input, in-memory execution.  Tiled runs hold a memory reservation
    sized to *their* plan and batched graphs already own the leading
    axis — both dispatch solo."""
    return (not P.batched
            and tiles is None and memory_budget is None
            and not isinstance(P.x, jax.core.Tracer))


class Coalescer:
    """Open windows keyed by plan key; pure data structure, loop-owned.

    The clock is injected (``clock=time.monotonic`` by default) so the
    window/deadline logic is unit-testable without sleeping.
    """

    def __init__(self, max_batch: int = 8, max_wait: float = 0.002,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.clock = clock
        #: key -> (deadline, [requests]); insertion-ordered so expiry
        #: scans oldest-first
        self._open: "OrderedDict[tuple, list]" = OrderedDict()
        self._pending = 0

    @property
    def pending(self) -> int:
        """Requests staged in open windows (not yet in any batch)."""
        return self._pending

    def has_open(self, key) -> bool:
        return key is not None and key in self._open

    def offer(self, req: Request) -> List[Batch]:
        """Stage one request; returns the batches this arrival closed
        (a full window, or a solo batch for non-coalescible work)."""
        if not req.coalescible:
            return [Batch(None, [req])]
        entry = self._open.get(req.key)
        if entry is None:
            entry = self._open[req.key] = [self.clock() + self.max_wait, []]
        entry[1].append(req)
        self._pending += 1
        if len(entry[1]) >= self.max_batch:
            return [self._close(req.key)]
        return []

    def _close(self, key) -> Batch:
        _, reqs = self._open.pop(key)
        self._pending -= len(reqs)
        return Batch(key, reqs)

    def poll(self, now: Optional[float] = None) -> List[Batch]:
        """Close every window whose deadline has passed."""
        now = self.clock() if now is None else now
        expired = [k for k, (dl, _) in self._open.items() if dl <= now]
        return [self._close(k) for k in expired]

    def next_deadline(self) -> Optional[float]:
        """Earliest open-window deadline (``None`` when no windows)."""
        return min((dl for dl, _ in self._open.values()), default=None)

    def flush_all(self) -> List[Batch]:
        """Close everything (drain-on-shutdown)."""
        return [self._close(k) for k in list(self._open)]


# -- batch execution ---------------------------------------------------------


def _opts_of(req: Request, batched: bool) -> ExecOptions:
    return ExecOptions.make(method=req.method, pad_value=req.pad_value,
                            batched=batched, out_dtype=req.out_dtype)


def batch_cache_key(reqs: List[Request]) -> Optional[tuple]:
    """The plan-cache key a stacked dispatch of ``reqs`` interns under,
    or ``None`` when the stacked run does not hit the pipe-plan cache
    (single-op graphs lower onto the legacy plan kinds; split-terminal
    graphs intern under their producer prefix).  The admission
    controller probes this with :func:`repro.core.plan.plan_cached` to
    tell a warm batched plan from a cold one it has never seen."""
    r0 = reqs[0]
    P = r0.pipe
    if len(P.ops) < 2 or isinstance(P.ops[-1], (HistOp, CovOp)):
        return None
    opts = _opts_of(r0, batched=True)
    shape = (len(reqs),) + tuple(P.x.shape)
    return ("pipe", shape, jnp.dtype(P.x.dtype).name, True, opts.key(),
            P.signature())


def _slice_state(state, i: int):
    return jax.tree_util.tree_map(lambda leaf: leaf[i], state)


def _stack_inputs(reqs: List[Request]):
    """One device transfer, not eight: stack host-side when every input
    is a numpy array (the common serving case — ``jnp.stack`` over N
    small device arrays costs N device_puts plus a concat and was
    measured slower than the batched run it feeds)."""
    arrs = [r.pipe.x for r in reqs]
    if all(isinstance(a, np.ndarray) for a in arrs):
        return jnp.asarray(np.stack(arrs))
    return jnp.stack([jnp.asarray(a) for a in arrs])


def begin_batch(reqs: List[Request], budget=None) -> Callable[[], list]:
    """Dispatch phase of one batch: stack the inputs and *launch* the
    device work without host synchronization; returns a zero-arg
    ``collect`` whose call finishes the transfer and yields per-request
    results in request order.

    jax dispatch is asynchronous, so a worker holding several ready
    batches begins them all back-to-back — the device pipelines the
    stacked executions — before collecting any; this took ~15% off an
    8-batch makespan vs dispatching-and-blocking one batch at a time
    (``benchmarks/serve.py``).  Tiled streams synchronize internally,
    so that path defers *everything* to ``collect`` — beginning it
    eagerly would stall the group's remaining dispatches behind a
    whole out-of-core stream.
    """
    if len(reqs) == 1:
        r = reqs[0]
        if r.tiles is not None or r.memory_budget is not None:
            def collect_tiled():
                from repro.pipe.tiled import run_tiled

                return [jax.device_get(run_tiled(
                    r.pipe, tiles=r.tiles, memory_budget=r.memory_budget,
                    method=r.method, pad_value=r.pad_value,
                    out_dtype=r.out_dtype, budget=budget))]
            return collect_tiled
        out = _compile.run(r.pipe, method=r.method, pad_value=r.pad_value,
                           out_dtype=r.out_dtype)
        return lambda: [jax.device_get(out)]

    r0 = reqs[0]
    P = r0.pipe
    xs = _stack_inputs(reqs)
    terminal = P.ops[-1] if P.ops else None
    if isinstance(terminal, (HistOp, CovOp)):
        # batched hist/cov merge the whole stack into ONE state — split
        # the terminal off and vmap it over the batched producer output
        producer = Pipe(xs, batched=True, ops=P.ops[:-1])
        out = _compile.run(producer, method=r0.method,
                           pad_value=r0.pad_value, out_dtype=r0.out_dtype)
        if isinstance(terminal, HistOp):
            counts = jax.vmap(lambda t: histogram_fixed_counts(
                t, terminal.bins, terminal.lo, terminal.hi))(out)

            def collect_hist():
                h = np.asarray(counts)
                from repro.stats.hist import Histogram

                return [Histogram(h[i], terminal.lo, terminal.hi)
                        for i in range(len(reqs))]
            return collect_hist
        from repro.stats.cov import channel_cov

        state = jax.vmap(channel_cov)(out)

        def collect_cov():
            host = jax.device_get(state)
            return [_slice_state(host, i) for i in range(len(reqs))]
        return collect_cov
    # warm fast path: the admission controller only dispatches batches
    # whose plan is interned, so probe the cache directly and skip the
    # per-call option/key/LRU work of compile.run (measured ~20% of a
    # warm batch dispatch); any miss falls back to the full path
    ck = batch_cache_key(reqs)
    plan = plan_cached(ck) if ck is not None else None
    if plan is not None:
        out = plan(xs)
    else:
        stacked = Pipe(xs, batched=True, ops=P.ops)
        out = _compile.run(stacked, method=r0.method,
                           pad_value=r0.pad_value, out_dtype=r0.out_dtype)
    if isinstance(out, jax.Array):
        def collect_array():
            host = np.asarray(out)
            return [host[i] for i in range(len(reqs))]
        return collect_array

    def collect_state():
        # moments state: leaves carry the leading batch axis
        host = jax.device_get(out)
        return [_slice_state(host, i) for i in range(len(reqs))]
    return collect_state


def execute_batch(reqs: List[Request], budget=None) -> list:
    """Run one batch to completion; per-request results in request order.

    ``begin_batch(reqs, budget)()`` — dispatch immediately followed by
    collect.  **Results are host-side**: array outputs come back as
    numpy arrays and state pytrees with numpy leaves (one
    ``device_get`` per batch — per-item device slicing costs a dispatch
    per request and was measured to eat the whole coalescing win; the
    answer crosses a thread boundary to a waiting caller anyway).

    Size-1 batches take the direct path (including tiled execution,
    holding ``budget`` for the stream's working set); larger batches
    stack inputs and unstack results per the terminal taxonomy in the
    module docstring.  Raises on failure — the service fails every
    future in the batch with the same exception (they shared one
    dispatch, so they share its fate).
    """
    return begin_batch(reqs, budget)()


def histogram_fixed_counts(t, bins, lo, hi):
    """vmap-friendly face of :func:`repro.stats.hist.histogram_fixed`:
    returns the counts array alone (the Histogram container's lo/hi are
    static aux, rebuilt outside the vmap)."""
    from repro.stats.hist import histogram_fixed

    return histogram_fixed(t, bins, lo, hi).counts
