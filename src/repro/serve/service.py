"""The :class:`PipeService` — submit/await serving of pipe programs.

Concurrency model (DESIGN.md §15): one asyncio event loop runs in a
dedicated daemon thread and **owns every piece of mutable state** —
the :class:`~repro.serve.backpressure.FairQueue`, the
:class:`~repro.serve.coalesce.Coalescer`'s open windows, the
:class:`~repro.serve.admission.AdmissionController`, the ready deque
and the in-flight count.  Caller threads only ever
``call_soon_threadsafe`` into the loop; batch execution happens on a
``ThreadPoolExecutor`` of ``workers`` threads (jax dispatch releases
the GIL, so workers overlap).  A finished batch resolves its tickets
directly on the worker thread — callers wake immediately — while the
bookkeeping (in-flight count, admission release, next pump) hops back
onto the loop.  No state needs a lock, and the pump logic stays
sequential enough to reason about.

The pump, run on every arrival / window expiry / completion:

1. close expired coalescing windows into ready batches;
2. drain the fair queue into the coalescer while the staging area has
   room (``(2 × workers + dispatch_ahead) × max_batch`` — enough to
   keep windows filling ahead of the dispatch slots without unbounded
   staging);
3. while a dispatch slot is free (``workers + dispatch_ahead`` — the
   ahead slots keep the executor's own queue primed so a freeing
   worker never waits out the completion's hop through the loop),
   pick the first *admissible* ready batch (cold-plan verdicts per
   :class:`AdmissionController`: parked batches stay ready and re-try
   on the next completion); the picks dispatch as at most ``workers``
   *groups*, each group one executor task that **begins every batch
   before collecting any** so the device pipelines the stacked
   executions (:func:`~repro.serve.coalesce.begin_batch`);
4. re-arm the single timer for the earliest remaining window deadline.

Metrics ride the PR-8 registry: counters ``serve/submitted``,
``serve/served``, ``serve/shed``, ``serve/failed``,
``serve/rejected_cold``, ``serve/batches``, ``serve/coalesced``;
gauges ``serve/queue_depth``, ``serve/inflight``; histograms
``serve/latency_ms`` (default ms edges) and ``serve/batch_size``.
Each dispatched batch runs under a ``serve/batch`` span.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import counter as _counter, gauge as _gauge, \
    histogram as _histogram
from repro.obs.trace import span as _span
from repro.pipe import compile as _compile
from repro.pipe.graph import Pipe
from repro.serve.admission import AdmissionController, ColdPlanOverload, \
    MemoryBudget
from repro.serve.backpressure import FairQueue, ShedError
from repro.serve.coalesce import Batch, Coalescer, Request, \
    batch_cache_key, begin_batch, coalescible, execute_batch

__all__ = ["ServeConfig", "PipeService", "Program", "Ticket",
           "ServiceClosed"]

#: serve/batch_size histogram edges (counts, not ms)
BATCH_SIZE_EDGES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)


class ServiceClosed(RuntimeError):
    """Submitted to (or pending in) a service that has shut down."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs; every field has a sane small-deployment default."""

    #: coalescing window cap — PR 1 measured B=8 at 3–6x over 8 solo runs
    max_batch: int = 8
    #: how long the first request of a window waits for company
    max_wait_ms: float = 2.0
    #: global bound on queued (not yet staged) requests
    queue_depth: int = 256
    #: per-tenant queued-request cap (None = no per-tenant cap)
    tenant_quota: Optional[int] = None
    #: executor threads — each runs one batch at a time
    workers: int = 2
    #: ready batches dispatched into the executor *beyond* the worker
    #: count, so a freeing worker starts the next batch immediately
    #: instead of idling while the completion hops through the event
    #: loop (a ~100-300µs bubble per batch that adds up at high rate),
    #: and so one pump can hand a worker a whole *group* of batches to
    #: begin back-to-back before collecting any (the device pipelines
    #: them).  Counts toward the in-flight capacity the shed threshold
    #: sees; staging scales with it so the extra slots have ready work.
    dispatch_ahead: int = 1
    #: concurrent *distinct* cold-plan traces allowed
    max_cold_plans: int = 2
    #: over-cap cold batches: "queue" (park) or "reject" (fail fast)
    cold_policy: str = "queue"
    #: full-queue policy: "reject-new" or "shed-largest"
    shed_policy: str = "reject-new"
    #: shared byte budget for concurrent tiled streams (None = unmetered)
    memory_budget: Optional[int] = None


class Ticket:
    """The caller's handle: a thin veneer over the request's future."""

    __slots__ = ("_req",)

    def __init__(self, req: Request):
        self._req = req

    @property
    def id(self) -> int:
        return self._req.id

    @property
    def tenant(self) -> str:
        return self._req.tenant

    @property
    def latency(self) -> Optional[float]:
        """Submit→resolve seconds (None until served)."""
        return self._req.latency

    def done(self) -> bool:
        return self._req.future.done()

    def result(self, timeout: Optional[float] = None):
        return self._req.future.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self._req.future.exception(timeout)


class Program:
    """A registered pipe program: graph captured once, data per request.

    Created by :meth:`PipeService.register`.  ``submit(x)`` binds one
    input array to the captured op chain and enqueues it — the serving
    analogue of holding a compiled model and sending it data.  Per-shape
    plan keys are computed once and cached, so the per-request cost is a
    dict probe plus the enqueue, not graph construction + key hashing
    (which dominates the caller thread when every request rebuilds its
    graph).  Thread-safe: the key cache is a plain dict mutated only by
    whole-entry assignment.
    """

    __slots__ = ("_svc", "ops", "method", "pad_value", "out_dtype", "_keys")

    def __init__(self, svc: "PipeService", ops: tuple, method: str,
                 pad_value, out_dtype):
        self._svc = svc
        self.ops = tuple(ops)
        self.method = method
        self.pad_value = pad_value
        self.out_dtype = out_dtype
        self._keys: dict = {}

    def submit(self, x, *, tenant: str = "default") -> Ticket:
        """Enqueue the registered program over ``x``; returns a
        :class:`Ticket`.  Coalesces with any same-key request, including
        graph-carrying :meth:`PipeService.submit` calls — the plan key,
        not the submission path, decides batchability."""
        if isinstance(x, jax.core.Tracer):
            raise ValueError(
                "PipeService serves concrete inputs; a traced pipeline "
                "belongs inside its own jit, not on the request path")
        if not hasattr(x, "shape") or not hasattr(x, "dtype"):
            x = jnp.asarray(x)
        P = Pipe(x, batched=False, ops=self.ops)
        sig = (tuple(x.shape), str(x.dtype))
        key = self._keys.get(sig)
        if key is None:
            key = _compile.plan_key_for(P, method=self.method,
                                        pad_value=self.pad_value,
                                        out_dtype=self.out_dtype)
            self._keys[sig] = key
        return self._svc._enqueue(P, self.method, self.pad_value,
                                  self.out_dtype, None, None,
                                  str(tenant), key)


class PipeService:
    """Accepts pipe-program requests and serves them batched.

    ``execute=`` is the test seam: a callable ``(requests, budget) ->
    results`` replacing :func:`repro.serve.coalesce.execute_batch` on
    the worker threads (e.g. an artificially slow executor to exercise
    shedding).  ``clock=`` feeds the coalescer's window deadlines.
    """

    def __init__(self, config: Optional[ServeConfig] = None, *,
                 execute=None, clock=time.monotonic):
        cfg = config if config is not None else ServeConfig()
        if cfg.workers < 1:
            raise ValueError(f"workers must be >= 1, got {cfg.workers}")
        if cfg.dispatch_ahead < 0:
            raise ValueError(f"dispatch_ahead must be >= 0, "
                             f"got {cfg.dispatch_ahead}")
        self.config = cfg
        self._clock = clock
        self._execute = execute if execute is not None else execute_batch
        # custom executors have no dispatch/collect split — defer the
        # whole call to the collect phase so the one-call-per-batch
        # test seam keeps its shape
        self._begin = (begin_batch if execute is None
                       else lambda reqs, budget: lambda: execute(reqs, budget))
        self.budget = (MemoryBudget(cfg.memory_budget)
                       if cfg.memory_budget is not None else None)

        # loop-owned state (every mutation happens on the loop thread)
        self._queue = FairQueue(cfg.queue_depth, cfg.tenant_quota,
                                cfg.shed_policy)
        self._coal = Coalescer(cfg.max_batch, cfg.max_wait_ms / 1e3, clock)
        self._admission = AdmissionController(cfg.max_cold_plans,
                                              cfg.cold_policy)
        self._ready: "deque[Batch]" = deque()
        self._inflight = 0
        self._outstanding = 0
        self._draining = False
        self._drained: Optional[threading.Event] = None
        self._timer = None
        #: submit → loop handoff: callers append (GIL-atomic) and wake
        #: the loop only when no drain is already scheduled, so a burst
        #: of submits costs one wakeup + one pump, not one per request
        self._pending: "deque[Request]" = deque()
        self._ingest_scheduled = False

        self._ids = itertools.count()
        self._closed = False
        self._terminated = False
        self._pool = ThreadPoolExecutor(max_workers=cfg.workers,
                                        thread_name_prefix="repro-serve")
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._loop.run_forever,
                                        name="repro-serve-loop", daemon=True)
        self._thread.start()

    # -- client API --------------------------------------------------------
    def submit(self, P: Pipe, *, method: str = "auto", pad_value="edge",
               out_dtype=None, tiles=None, memory_budget=None,
               tenant: str = "default") -> Ticket:
        """Enqueue one pipeline run; returns immediately with a
        :class:`Ticket` whose ``result()`` blocks for the answer.

        Validation (bad options, ``out_dtype`` on a state terminal,
        tracer inputs) raises *here*, synchronously.  Backpressure
        verdicts are asynchronous by nature — a shed request's ticket
        raises :class:`~repro.serve.backpressure.ShedError`, a cold-plan
        rejection :class:`~repro.serve.admission.ColdPlanOverload`.

        The same ``(graph, shape, dtype, options)`` submitted while a
        coalescing window is open joins it and shares one batched
        dispatch; array-valued results are bit-identical to
        ``P.run(...)``, ``moments`` states match to float tolerance
        (DESIGN.md §15 records why).
        """
        if isinstance(P.x, jax.core.Tracer):
            raise ValueError(
                "PipeService serves concrete inputs; a traced pipeline "
                "belongs inside its own jit, not on the request path")
        if tiles is not None and memory_budget is not None:
            raise ValueError("pass at most one of tiles= / "
                             "memory_budget= per request")
        # full validation in the caller's thread — plan_key_for builds
        # the (normalized) options and runs the out_dtype/terminal check
        key = _compile.plan_key_for(P, method=method, pad_value=pad_value,
                                    out_dtype=out_dtype)
        if not coalescible(P, tiles, memory_budget):
            key = None
        return self._enqueue(P, method, pad_value, out_dtype, tiles,
                             memory_budget, str(tenant), key)

    def register(self, P: Pipe, *, method: str = "auto", pad_value="edge",
                 out_dtype=None) -> Program:
        """Capture ``P``'s op chain as a :class:`Program` whose
        ``submit(x)`` binds data only.  The template's input supplies
        nothing but validation fodder; each submitted array may have any
        shape/dtype the graph accepts (per-shape plan keys are cached).
        Validation of the option set against the graph happens here,
        synchronously — a bad ``out_dtype``/terminal combination never
        reaches the request path."""
        if self._closed:
            raise ServiceClosed("register on a closed PipeService")
        if P.batched:
            raise ValueError("register takes an unbatched template graph "
                             "(the service stacks the batch axis itself)")
        _compile.plan_key_for(P, method=method, pad_value=pad_value,
                              out_dtype=out_dtype)
        return Program(self, P.ops, method, pad_value, out_dtype)

    def _enqueue(self, P: Pipe, method, pad_value, out_dtype, tiles,
                 memory_budget, tenant: str, key) -> Ticket:
        if self._closed:
            raise ServiceClosed("submit on a closed PipeService")
        req = Request(id=next(self._ids), pipe=P, method=method,
                      pad_value=pad_value, out_dtype=out_dtype,
                      tiles=tiles, memory_budget=memory_budget,
                      tenant=tenant, future=Future(),
                      t_submit=self._clock(), key=key)
        _counter("serve/submitted").inc()
        self._pending.append(req)
        if not self._ingest_scheduled:
            # the drain resets the flag BEFORE popping, so a caller that
            # reads a stale True has appended to a deque the in-progress
            # drain is still emptying — no request is ever stranded
            self._ingest_scheduled = True
            self._loop.call_soon_threadsafe(self._drain_pending)
        return Ticket(req)

    def warmup(self, P: Pipe, batch_sizes: Optional[Tuple[int, ...]] = None,
               *, method: str = "auto", pad_value="edge",
               out_dtype=None) -> int:
        """Pre-trace ``P``'s executors at the given batch sizes (default
        solo + ``max_batch``) by running zeros of the template's shape
        through the real batch path, then mark those keys warm for
        admission.  Returns the number of executors traced.  Synchronous
        — call before opening the doors, so the first real requests hit
        compiled plans."""
        if P.batched:
            raise ValueError("warmup takes an unbatched template graph "
                             "(the service stacks the batch axis itself)")
        key = _compile.plan_key_for(P, method=method, pad_value=pad_value,
                                    out_dtype=out_dtype)
        sizes = sorted({int(b) for b in
                        (batch_sizes if batch_sizes is not None
                         else (1, self.config.max_batch))})
        if any(b < 1 for b in sizes):
            raise ValueError(f"batch sizes must be >= 1, got {sizes}")
        zeros = jnp.zeros(tuple(P.x.shape), jnp.dtype(P.x.dtype))
        P0 = Pipe(zeros, batched=False, ops=P.ops)
        for B in sizes:
            reqs = [Request(id=-1, pipe=P0, method=method,
                            pad_value=pad_value, out_dtype=out_dtype,
                            tiles=None, memory_budget=None,
                            tenant="warmup", future=Future(),
                            t_submit=self._clock(), key=key)
                    for _ in range(B)]
            with _span("serve/warmup", batch=B):
                self._execute(reqs, self.budget)
            akey = (key, B)
            self._loop.call_soon_threadsafe(self._admission.release, akey)
        _counter("serve/warmed").inc(len(sizes))
        return len(sizes)

    def stats(self) -> dict:
        """A loop-consistent snapshot of the service's moving parts."""
        box, got = {}, threading.Event()

        def grab():
            box.update(
                queue_depth=len(self._queue),
                queued_by_tenant=self._queue.depths(),
                staged=self._coal.pending,
                ready_batches=len(self._ready),
                inflight=self._inflight,
                outstanding=self._outstanding,
                warm_keys=self._admission.warm_keys(),
                closed=self._closed)
            got.set()

        self._loop.call_soon_threadsafe(grab)
        got.wait(5.0)
        if self.budget is not None:
            box["budget"] = {"total": self.budget.total,
                             "in_use": self.budget.in_use,
                             "peak": self.budget.peak,
                             "waits": self.budget.waits}
        return box

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Shut down.  ``drain=True`` (default) first serves everything
        already accepted — queued, staged in open windows, and in flight
        — then stops; ``drain=False`` fails all pending tickets with
        :class:`ServiceClosed` (in-flight batches still finish).  New
        ``submit`` calls raise immediately either way.  Idempotent."""
        if self._terminated:
            return
        self._closed = True
        done = threading.Event()
        self._loop.call_soon_threadsafe(self._begin_close, drain, done)
        done.wait(timeout)
        self._terminated = True
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout if timeout is not None else 30.0)
        self._pool.shutdown(wait=True)
        self._loop.close()

    def __enter__(self) -> "PipeService":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # -- loop side ---------------------------------------------------------
    def _drain_pending(self) -> None:
        self._ingest_scheduled = False
        ingested = False
        while self._pending:
            req = self._pending.popleft()
            if len(self._queue) >= self.config.queue_depth:
                # a burst larger than the queue: give staging/dispatch a
                # chance to absorb before shedding, exactly as if the
                # requests had arrived one pump apart
                self._pump()
            self._ingest(req)
            ingested = True
        if ingested:
            self._pump()

    def _ingest(self, req: Request) -> None:
        if self._draining:
            req.future.set_exception(
                ServiceClosed("service closed while request in transit"))
            return
        try:
            displaced = self._queue.put(req, req.tenant)
        except ShedError as e:
            _counter("serve/shed").inc()
            req.future.set_exception(e)
            return
        self._outstanding += 1
        if displaced is not None:
            self._outstanding -= 1
            _counter("serve/shed").inc()
            displaced.future.set_exception(ShedError(
                f"displaced by a newer request under shed-largest "
                f"(queue depth {self._queue.depth})", "queue-full"))

    def _staged(self) -> int:
        """Requests past the queue but not yet dispatched: open windows
        plus closed-but-undispatched batches.  The staging cap counts
        BOTH — otherwise small-window configs would leak the whole
        queue into the unbounded ready deque and the shed threshold
        would never be reached."""
        return self._coal.pending + sum(len(b) for b in self._ready)

    def _pump(self) -> None:
        now = self._clock()
        self._ready.extend(self._coal.poll(now))
        # stage: keep the coalescer fed, but bounded — the queue is the
        # backpressure surface, not the staging area.  The ahead slots
        # need ready batches to prime, so staging scales with them.
        cap = ((2 * self.config.workers + self.config.dispatch_ahead)
               * self.config.max_batch)
        while len(self._queue) and self._staged() < cap:
            req, _tenant = self._queue.get()
            self._ready.extend(self._coal.offer(req))
        if self._draining:
            # no point waiting out window deadlines during drain
            self._ready.extend(self._coal.flush_all())
        _gauge("serve/queue_depth").set(len(self._queue))

        slots = self.config.workers + self.config.dispatch_ahead
        picked = []
        while self._inflight + len(picked) < slots and self._ready:
            choice = None
            for b in list(self._ready):
                akey = (b.key, len(b)) if b.key is not None else None
                if akey is None:
                    choice = (b, None)
                    break
                verdict = self._admission.try_acquire(
                    akey, batch_cache_key(b.requests))
                if verdict == "run":
                    choice = (b, akey)
                    break
                if verdict == "reject":
                    self._ready.remove(b)
                    self._outstanding -= len(b)
                    _counter("serve/rejected_cold").inc(len(b))
                    err = ColdPlanOverload(
                        f"{self._admission.max_cold} cold plans already "
                        f"compiling; retry once the service warms")
                    for r in b.requests:
                        r.future.set_exception(err)
                # "wait": parked in ready until a release re-pumps
            if choice is None:
                break
            self._ready.remove(choice[0])
            picked.append(choice)
        self._dispatch(picked)

        self._arm_timer()
        if (self._draining and self._drained is not None
                and self._outstanding == 0):
            self._drained.set()

    def _arm_timer(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        dl = self._coal.next_deadline()
        if dl is not None:
            delay = max(0.0, dl - self._clock())
            self._timer = self._loop.call_later(delay, self._on_timer)

    def _on_timer(self) -> None:
        self._timer = None
        self._pump()

    def _dispatch(self, picked) -> None:
        """Send this pump's admitted ``(batch, akey)`` picks to the
        executor, split round-robin into at most ``workers`` groups —
        each group is ONE executor task whose worker *begins* every
        batch before collecting any, so the device pipelines the
        dispatches (:func:`~repro.serve.coalesce.begin_batch`)."""
        if not picked:
            return
        for b, _akey in picked:
            self._inflight += 1
            _counter("serve/batches").inc()
            if len(b) > 1:
                _counter("serve/coalesced").inc(len(b) - 1)
            _histogram("serve/batch_size", BATCH_SIZE_EDGES).observe(len(b))
        _gauge("serve/inflight").set(self._inflight)
        ngroups = min(len(picked), self.config.workers)
        for i in range(ngroups):
            self._pool.submit(self._run_group, picked[i::ngroups])

    def _run_group(self, group) -> None:  # worker thread
        """Begin every batch in the group (async dispatch — device
        work for batch *i+1* launches while batch *i* still computes),
        then collect and complete each in begin order.  Tickets resolve
        right here on the worker: a caller blocked in ``Ticket.result``
        wakes the moment its batch finishes, without waiting for the
        completion to hop through the event loop first.  The loop-owned
        bookkeeping (in-flight count, admission release, next pump) is
        scheduled *before* the futures resolve, so anything a woken
        caller then schedules onto the loop (``stats()``, ``close()``)
        is ordered after it.  Metric objects are internally locked —
        safe off-loop."""
        begun = []
        for b, akey in group:
            try:
                begun.append((b, akey, self._begin(b.requests, self.budget),
                              None))
            except BaseException as e:  # noqa: BLE001 — routed to tickets
                begun.append((b, akey, None, e))
        for b, akey, collect, error in begun:
            if error is None:
                try:
                    with _span("serve/batch", size=len(b),
                               coalesced=int(b.key is not None)):
                        results = collect()
                except BaseException as e:  # noqa: BLE001 — to tickets
                    error = e
            self._loop.call_soon_threadsafe(self._complete, b, akey)
            if error is not None:
                _counter("serve/failed").inc(len(b))
                for r in b.requests:
                    r.future.set_exception(error)
                continue
            now = self._clock()
            lat_ms = _histogram("serve/latency_ms")
            _counter("serve/served").inc(len(b))
            for r, res in zip(b.requests, results):
                r.latency = now - r.t_submit
                lat_ms.observe(r.latency * 1e3)
                r.future.set_result(res)

    def _complete(self, b: Batch, akey) -> None:
        self._inflight -= 1
        _gauge("serve/inflight").set(self._inflight)
        if akey is not None:
            # even a failed dispatch leaves the executor interned — the
            # plan cache built it before the run could fail
            self._admission.release(akey)
        self._outstanding -= len(b)
        self._pump()

    def _begin_close(self, drain: bool, done: threading.Event) -> None:
        # in-transit submits first: accept them ahead of the drain flag
        # so a ticket handed out before close() is served, not orphaned
        while self._pending:
            self._ingest(self._pending.popleft())
        self._draining = True
        self._drained = done
        if not drain:
            err = ServiceClosed("service closed without draining")
            for req, _tenant in self._queue.drain():
                self._outstanding -= 1
                req.future.set_exception(err)
            for b in (self._coal.flush_all() + list(self._ready)):
                self._outstanding -= len(b)
                for r in b.requests:
                    r.future.set_exception(err)
            self._ready.clear()
        self._pump()
