"""Deterministic fault injection for out-of-core tile streams.

The failure model of a long-running tiled stream (DESIGN.md §13) has
three boundaries where the host scheduler talks to something that can
break independently of the program logic:

- ``'read'``       — the host-side patch read (a memmap page-in, an NFS
  volume, an object-store GET);
- ``'device'``     — the device compute dispatch (a preempted
  accelerator, an XLA transient, a flaky interconnect);
- ``'writeback'``  — the device→host result placement (the D2H copy or
  the destination buffer/file write).

Faults come in two kinds, mirroring what recovery can do about them:

- **transient** — goes away if you retry (``TransientFault``); the
  stream's bounded per-tile retry must absorb these;
- **permanent** — every retry fails (``PermanentFault``); the tile is
  *quarantined* and the stream degrades gracefully (``strict=False``)
  or raises with the full :class:`~repro.pipe.tiled.FaultReport`
  attached (``strict=True``).

:class:`FaultInjector` raises these at the boundaries of
``repro.pipe.tiled`` **deterministically**: whether tile ``i`` faults at
site ``s`` is a pure function of ``(seed, site, tile)``, so a failing
chaos run reproduces exactly from its seed.  ``kill_after=`` simulates a
whole-process crash (SIGKILL mid-stream) by raising
:class:`StreamKilled` once ``k`` tiles have entered device compute —
the checkpoint/resume tests interrupt runs with it.

The injector is *test/chaos infrastructure shipped as library code*: the
production stream runs with :data:`NO_FAULTS` (every check inlines to a
no-op), and real exceptions raised by real boundaries flow through the
same retry/quarantine policy — user code can raise ``TransientFault``
from a flaky reader to opt into bounded retries.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

from repro.obs.trace import instant as _instant

__all__ = [
    "SITES",
    "TransientFault",
    "PermanentFault",
    "StreamKilled",
    "FaultSpec",
    "FaultInjector",
    "NO_FAULTS",
]

#: the three injectable boundaries of a tiled stream, in pipeline order
SITES = ("read", "device", "writeback")


class TransientFault(RuntimeError):
    """A fault that clears on retry (preemption blip, flaky I/O)."""

    def __init__(self, site: str, tile: int, attempt: int):
        self.site = site
        self.tile = tile
        self.attempt = attempt
        super().__init__(
            f"transient fault at {site!r} boundary, tile {tile} "
            f"(attempt {attempt})")


class PermanentFault(RuntimeError):
    """A fault no retry fixes (bad block, poisoned input tile)."""

    def __init__(self, site: str, tile: int):
        self.site = site
        self.tile = tile
        super().__init__(f"permanent fault at {site!r} boundary, "
                         f"tile {tile}")


class StreamKilled(RuntimeError):
    """Simulated whole-process death mid-stream (kill -9 semantics).

    Raised *between* tiles, never caught by the per-tile retry policy:
    it models the crash the journal/snapshot machinery exists to
    survive.  Re-running with the same ``checkpoint_dir`` resumes.
    """

    def __init__(self, after_tiles: int):
        self.after_tiles = after_tiles
        super().__init__(
            f"stream killed after {after_tiles} tile(s) entered compute "
            f"(simulated crash; resume from the checkpoint dir)")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One seeded fault population: which boundary, which kind, how many.

    ``rate`` is the fraction of tiles hit at ``site`` (selection is
    deterministic per tile from the injector seed).  For transient
    faults, ``failures`` is how many consecutive attempts fail before
    the fault clears — ``failures <= max_retries`` is recoverable,
    ``failures > max_retries`` exhausts the retry budget and
    quarantines like a permanent fault.
    """

    site: str
    kind: str = "transient"
    rate: float = 1.0
    failures: int = 1

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; expected "
                             f"one of {', '.join(SITES)}")
        if self.kind not in ("transient", "permanent"):
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"'transient' or 'permanent'")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.failures < 1:
            raise ValueError(f"failures must be >= 1, got {self.failures}")


class FaultInjector:
    """Raises seeded faults at the stream's boundaries.

    ``check(site, tile, attempt)`` is called by the tiled runner before
    each boundary crossing; it either returns (no fault for this
    ``(site, tile)``) or raises the scheduled fault.  Selection is a
    pure function of ``(seed, site, tile)`` — re-running the same
    stream with the same injector faults the same tiles, which is what
    makes chaos runs reproducible and the kill/resume tests exact.

    ``kill_after=k`` raises :class:`StreamKilled` when the ``k+1``-th
    *distinct first attempt* reaches the device boundary (i.e. after
    ``k`` tiles entered compute).  The kill fires once per injector by
    default (``kill_once=True``): the same injector object carried into
    the resumed run will not re-kill, mimicking a crash that does not
    recur.
    """

    def __init__(self, specs: Tuple[FaultSpec, ...] = (), seed: int = 0,
                 kill_after: Optional[int] = None, kill_once: bool = True):
        self.specs = tuple(specs)
        for s in self.specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"specs must be FaultSpec, got {s!r}")
        self.seed = int(seed)
        if kill_after is not None and kill_after < 0:
            raise ValueError(f"kill_after must be >= 0, got {kill_after}")
        self.kill_after = kill_after
        self.kill_once = bool(kill_once)
        self._killed = False
        self._compute_entries = 0

    # -- deterministic selection -------------------------------------------
    def _u(self, site: str, tile: int) -> float:
        h = hashlib.sha256(f"{self.seed}:{site}:{tile}".encode()).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64

    def faults_at(self, site: str, tile: int) -> Optional[FaultSpec]:
        """The spec that hits ``(site, tile)``, or None (pure, no state)."""
        for spec in self.specs:
            if spec.site == site and self._u(site, tile) < spec.rate:
                return spec
        return None

    # -- the boundary hook --------------------------------------------------
    def check(self, site: str, tile: int, attempt: int = 0) -> None:
        if site == "device" and attempt == 0:
            if (self.kill_after is not None
                    and not (self.kill_once and self._killed)
                    and self._compute_entries >= self.kill_after):
                self._killed = True
                _instant("fault/inject", site=site, tile=int(tile),
                         kind="kill")
                raise StreamKilled(self._compute_entries)
            self._compute_entries += 1
        spec = self.faults_at(site, tile)
        if spec is None:
            return
        if spec.kind == "permanent":
            _instant("fault/inject", site=site, tile=int(tile),
                     kind="permanent")
            raise PermanentFault(site, tile)
        if attempt < spec.failures:
            _instant("fault/inject", site=site, tile=int(tile),
                     kind="transient", attempt=int(attempt))
            raise TransientFault(site, tile, attempt)


class _NoFaults(FaultInjector):
    """The production default: every check is a no-op."""

    def __init__(self):
        super().__init__()

    def check(self, site, tile, attempt=0):  # noqa: D102 — hot path
        return None


NO_FAULTS = _NoFaults()
