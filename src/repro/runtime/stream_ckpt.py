"""Crash-only progress for tiled streams: journal + snapshots (§13).

A :class:`~repro.pipe.tiled.TiledProgram` run with ``checkpoint_dir=``
persists its progress so a killed process resumes instead of restarting:

    <dir>/journal.jsonl        # append-only progress log (see below)
    <dir>/snap_<k>/            # atomic snapshot of the fold state after
        META.json              #   k tiles folded (reduction outputs)
        state.npz              #   stack leaves, one array per entry leaf
        _COMMITTED             # written LAST — uncommitted snaps ignored

Journal lines are single JSON objects:

    {"kind": "tiled-stream-journal", "version": 1, "fingerprint": ...,
     "num_tiles": N, "out_kind": ...}          # header, always first
    {"done": i}                                # tile i's result is durable
    {"quarantine": i, "site": ..., "fault": ..., "attempts": n,
     "error": ...}                             # tile i gave up (re-attempted
                                               # on resume — a new process
                                               # may not share the fault)
    {"snapshot": "snap_000000012"}             # fold state committed
    {"complete": true}                         # stream finished

Durability model — **process death, not host power loss**: appends are
written and fsync'd in cadence-sized chunks (every ``every`` lines and
at snapshot / completion boundaries), so a SIGKILL loses at most the
trailing unwritten entries — fewer than ``every`` — which resume simply
recomputes.  A torn trailing line (the
append the crash interrupted) is detected on load and truncated away
before new appends.

The caller's thread only ever appends (json + buffered write + flush,
microseconds); every blocking disk operation — journal fsyncs and the
whole snapshot stage/fsync/rename/prune sequence — runs on a single
background writer thread, so durability costs overlap the stream's
compute instead of stalling the tile loop (the ``tiled/ckpt-overhead``
benchmark row gates this at ≤5%).  ``close()`` drains the writer, so
everything enqueued before a *graceful* stop (including the simulated
kills in the fault tests) is on disk when ``run()`` returns; a SIGKILL
can lose at most the enqueued-but-unwritten tail, which is exactly the
journal's recompute-on-resume contract.  Writer failures (disk full)
are re-raised on the caller's thread at the next checkpoint call or at
``close()``.

What "durable" means depends on the program's output:

- **array outputs** — a tile is journaled ``done`` only after its bytes
  landed in the caller's persistent buffer (``out=`` arena or
  ``out_path=`` memmap), so the done-set in the journal *is* the
  completed-box set and resume skips exactly those tiles;
- **reduction outputs** — per-tile states live in memory (the
  binary-counter fold), so durable progress is the latest committed
  *snapshot*: the exact fold stack plus the set of folded tiles.
  Restoring the stack and continuing the fold reproduces the
  uninterrupted merge tree node for node — resumed results are
  bit-identical on lax/materialize.

Every journal is keyed by a plan *fingerprint*
(:func:`repro.core.plan.plan_fingerprint` over graph signature ×
options × tiling × volume shape/dtype): resuming against a journal
written by any other plan raises instead of silently mixing results.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import re
import shutil
import threading
from typing import Optional, Tuple

import numpy as np

from repro.obs.trace import span as _span

__all__ = ["StreamCheckpoint", "ResumeState", "JOURNAL_NAME"]

JOURNAL_NAME = "journal.jsonl"
_SNAP_RE = re.compile(r"snap_(\d+)")


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# -- reduction-state serialization (the three mergeable kinds) ---------------


def _state_parts(state):
    """``(kind, aux, leaves)`` of one mergeable reduction state.

    Leaves are returned as-is (possibly still device-resident futures);
    the caller starts their D2H copies asynchronously and the writer
    thread collects the host values — a *blocking* ``device_get`` on
    either thread stalls the dispatch pipeline far beyond its own wall
    time, so nothing here is allowed to wait.
    """
    from repro.stats.cov import CovState
    from repro.stats.hist import Histogram
    from repro.stats.moments import MomentState

    if isinstance(state, MomentState):
        return "moments", {"order": int(state.order)}, [
            state.count, state.mean, state.m2, state.m3, state.m4]
    if isinstance(state, Histogram):
        return "hist", {"lo": float(state.lo), "hi": float(state.hi)}, [
            state.counts]
    if isinstance(state, CovState):
        return "cov", {}, [state.count, state.mean, state.comoment]
    raise TypeError(f"unknown reduction state {type(state).__name__}; "
                    f"snapshots carry MomentState/Histogram/CovState")


def _state_from_parts(kind: str, aux: dict, leaves):
    import jax.numpy as jnp

    from repro.stats.cov import CovState
    from repro.stats.hist import Histogram
    from repro.stats.moments import MomentState

    leaves = [jnp.asarray(x) for x in leaves]
    if kind == "moments":
        return MomentState(*leaves, order=int(aux["order"]))
    if kind == "hist":
        return Histogram(leaves[0], float(aux["lo"]), float(aux["hi"]))
    if kind == "cov":
        return CovState(*leaves)
    raise ValueError(f"unknown snapshot state kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class ResumeState:
    """What a resumed run starts from."""

    done: frozenset            # tile indices whose results are durable
    entries: Tuple             # restored fold stack: ((level, state), ...)
    snapshot: Optional[str]    # name of the snapshot restored (or None)
    complete: bool             # the previous run finished the stream


class StreamCheckpoint:
    """The journal/snapshot writer+reader for one checkpoint directory.

    Construction only records the expected identity; :meth:`load` binds
    to the directory — parsing (and fingerprint-validating) an existing
    journal, or writing a fresh header.  One instance serves one run.
    """

    def __init__(self, dir_: str, *, fingerprint: str, num_tiles: int,
                 out_kind: str, every: int = 8):
        self.dir = str(dir_)
        self.fingerprint = fingerprint
        self.num_tiles = int(num_tiles)
        self.out_kind = out_kind
        self.every = max(1, int(every))
        self._jf = None
        self._since_sync = 0
        self._buf: list = []
        self._q: Optional[queue.Queue] = None
        self._writer: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    # -- load / resume ------------------------------------------------------
    def load(self) -> Optional[ResumeState]:
        """Bind to the directory; the previous run's progress, or None.

        Raises ``ValueError`` when the directory holds a journal written
        by a *different* plan (stale fingerprint / tiling / out kind) —
        refusing is the whole point: a resumed fold must continue the
        exact plan that started it.
        """
        os.makedirs(self.dir, exist_ok=True)
        path = os.path.join(self.dir, JOURNAL_NAME)
        records, good_end = self._parse(path)
        if records is None:
            self._open(path, truncate_at=None, fresh=True)
            return None
        header, body = records[0], records[1:]
        for field, mine in (("fingerprint", self.fingerprint),
                            ("num_tiles", self.num_tiles),
                            ("out_kind", self.out_kind)):
            theirs = header.get(field)
            if theirs != mine:
                raise ValueError(
                    f"stale stream checkpoint at {self.dir!r}: journal "
                    f"{field} {theirs!r} does not match this plan's "
                    f"{mine!r} — the directory was written by a different "
                    f"(graph x tiling x dtype x pad) plan; resume with the "
                    f"original plan or use a fresh checkpoint_dir")
        done = set()
        complete = False
        for rec in body:
            if "done" in rec:
                done.add(int(rec["done"]))
            elif "complete" in rec:
                complete = True
        snap_name = self._latest_snapshot()
        entries: Tuple = ()
        if self.out_kind != "array":
            # durable reduction progress is the snapshot, not the journal:
            # per-tile states since the last snapshot died with the process
            done = set()
            if snap_name is not None:
                folded, entries = self._load_snapshot(snap_name)
                done = set(folded)
            complete = complete and not self._pending_after(done)
        self._open(path, truncate_at=good_end, fresh=False)
        return ResumeState(done=frozenset(done), entries=entries,
                           snapshot=snap_name, complete=complete)

    def _pending_after(self, done) -> bool:
        return len(done) < self.num_tiles

    def _parse(self, path: str):
        """``(records, offset-of-last-good-line-end)`` or ``(None, _)``
        for a missing/empty journal.  Parsing stops at the first torn or
        invalid line — everything after a torn write is suspect."""
        if not os.path.exists(path):
            return None, 0
        records, good_end = [], 0
        with open(path, "rb") as f:
            data = f.read()
        if not data.strip():
            return None, 0
        pos = 0
        while pos < len(data):
            nl = data.find(b"\n", pos)
            if nl < 0:
                break  # torn trailing line (no newline): drop it
            line = data[pos:nl]
            try:
                rec = json.loads(line.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError):
                break
            if not isinstance(rec, dict):
                break
            records.append(rec)
            good_end = nl + 1
            pos = nl + 1
        if not records or records[0].get("kind") != "tiled-stream-journal":
            raise ValueError(
                f"{path} is not a tiled-stream journal (bad or missing "
                f"header); refusing to append — use a fresh checkpoint_dir")
        return records, good_end

    def _open(self, path: str, truncate_at, fresh: bool):
        if fresh:
            self._jf = open(path, "w")
        else:
            if truncate_at is not None:
                with open(path, "r+b") as f:
                    f.truncate(truncate_at)
            self._jf = open(path, "a")
        self._q = queue.Queue()
        self._writer = threading.Thread(target=self._drain,
                                        name="stream-ckpt-writer",
                                        daemon=True)
        self._writer.start()
        if fresh:
            self._append({"kind": "tiled-stream-journal", "version": 1,
                          "fingerprint": self.fingerprint,
                          "num_tiles": self.num_tiles,
                          "out_kind": self.out_kind})
            self.sync()

    # -- the background writer ----------------------------------------------
    # The caller's thread stalls the tile stream for every millisecond it
    # spends in the filesystem, so ALL file work — appends, fsyncs, the
    # snapshot commit sequence — is enqueued here.  One thread, FIFO: the
    # worker is the sole owner of the journal fd between load() and
    # close(), and the on-disk line order matches the enqueue order
    # (dones → snapshot line → complete, exactly as a synchronous writer
    # would interleave them).  The first failure is latched and every
    # later job skipped — a journal that lost a write must not keep
    # appending as if durable — and re-raised on the caller's thread by
    # the next public call or close().
    def _drain(self):
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                if self._err is None:
                    # spans land on this writer thread's own trace track
                    if job[0] == "write":
                        with _span("ckpt/append", bytes=len(job[1])):
                            self._jf.write(job[1])
                            self._jf.flush()
                    elif job[0] == "sync":
                        with _span("ckpt/fsync"):
                            os.fsync(self._jf.fileno())
                    else:
                        with _span("ckpt/snapshot"):
                            self._commit_snapshot(*job[1:])
            except BaseException as e:  # latched, re-raised on caller
                self._err = e

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    # -- appends ------------------------------------------------------------
    # Appends are buffered on the caller and handed to the writer in
    # cadence-sized chunks: every Queue.put wakes the writer thread,
    # and each wake steals GIL slices from the dispatch-bound stream
    # loop — per-line handoff costs several times its own wall time.
    # A SIGKILL loses at most the buffered tail (< ``every`` lines),
    # which is already the journal's recompute-on-resume contract.
    def _append(self, rec: dict):
        if self._jf is None:  # pragma: no cover — misuse guard
            raise RuntimeError("StreamCheckpoint.load() must run first")
        self._raise_pending()
        self._buf.append(json.dumps(rec) + "\n")
        self._since_sync += 1
        if self._since_sync >= self.every:
            self.sync()

    def _flush_buf(self):
        if self._buf:
            self._q.put(("write", "".join(self._buf)))
            self._buf.clear()

    def sync(self):
        if self._q is not None:
            self._flush_buf()
            self._q.put(("sync",))
            self._since_sync = 0

    def tile_done(self, idx: int):
        self._append({"done": int(idx)})

    def quarantine(self, idx: int, site: str, fault: str, attempts: int,
                   error: str):
        self._append({"quarantine": int(idx), "site": site, "fault": fault,
                      "attempts": int(attempts), "error": error})

    def complete(self):
        # no explicit sync: close() drains the writer and fsyncs the
        # tail — one end-of-run fsync instead of two on the caller's
        # critical path
        self._append({"complete": True})

    def close(self):
        if self._writer is not None:
            self._flush_buf()
            self._q.put(None)
            self._writer.join()
            self._writer = None
            self._q = None
        if self._jf is not None:
            if self._since_sync:
                os.fsync(self._jf.fileno())
            self._jf.close()
            self._jf = None
        self._raise_pending()

    # -- snapshots (reduction fold state) -----------------------------------
    def snapshot(self, folded, entries):
        """Atomically commit the fold stack after ``len(folded)`` tiles.

        ``entries`` is the binary-counter stack — ``(level, state)``
        pairs, bottom first.  Temp-dir → fsync → rename → ``_COMMITTED``
        (the checkpoint.py discipline): a crash mid-snapshot leaves the
        previous snapshot authoritative.  Older snapshots are pruned
        after the new one commits.

        The caller only *starts* the (tiny) states' D2H copies — never
        blocks on them — and the writer thread collects the values and
        does the file I/O (stage, fsync, rename, prune); the snapshot is
        durable once :meth:`close` returns.
        """
        self._raise_pending()
        self._flush_buf()  # dones precede their snapshot line on disk
        folded = sorted(int(i) for i in folded)
        name = f"snap_{len(folded):09d}"
        staged = []
        for level, state in entries:
            kind, aux, leaves = _state_parts(state)
            for leaf in leaves:
                if hasattr(leaf, "copy_to_host_async"):
                    leaf.copy_to_host_async()
            staged.append((int(level), kind, aux, leaves))
        self._q.put(("snap", folded, name, staged))
        return name

    def _commit_snapshot(self, folded, name, staged):
        final = os.path.join(self.dir, name)
        tmp = final + f".tmp-{os.getpid()}"
        if os.path.isdir(tmp):  # leftover from a crashed attempt
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        try:
            meta_entries, arrays = [], {}
            for i, (level, kind, aux, leaves) in enumerate(staged):
                meta_entries.append({"level": level, "kind": kind,
                                     "aux": aux, "leaves": len(leaves)})
                for j, leaf in enumerate(leaves):
                    arrays[f"e{i}_l{j}"] = np.asarray(leaf)
            np.savez(os.path.join(tmp, "state.npz"), **arrays)
            with open(os.path.join(tmp, "META.json"), "w") as f:
                json.dump({"folded": folded, "entries": meta_entries}, f)
            for fname in ("state.npz", "META.json"):
                _fsync_path(os.path.join(tmp, fname))
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        with open(os.path.join(final, "_COMMITTED"), "w") as f:
            f.write("ok")
        _fsync_path(os.path.join(final, "_COMMITTED"))
        _fsync_path(self.dir)
        # already on the writer: append + fsync inline (going through
        # _append/sync would re-enqueue behind a possible close sentinel)
        self._jf.write(json.dumps({"snapshot": name}) + "\n")
        self._jf.flush()
        os.fsync(self._jf.fileno())
        self._prune(keep=name)
        return name

    def _snapshots(self):
        out = []
        for d in os.listdir(self.dir):
            m = _SNAP_RE.fullmatch(d)
            if m and os.path.exists(os.path.join(self.dir, d, "_COMMITTED")):
                out.append((int(m.group(1)), d))
        return sorted(out)

    def _latest_snapshot(self) -> Optional[str]:
        snaps = self._snapshots()
        return snaps[-1][1] if snaps else None

    def _prune(self, keep: str):
        for _, d in self._snapshots():
            if d != keep:
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)
        for d in os.listdir(self.dir):  # crashed temp attempts
            if ".tmp-" in d and d.startswith("snap_"):
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)

    def _load_snapshot(self, name: str):
        final = os.path.join(self.dir, name)
        with open(os.path.join(final, "META.json")) as f:
            meta = json.load(f)
        entries = []
        with np.load(os.path.join(final, "state.npz")) as z:
            for i, ent in enumerate(meta["entries"]):
                leaves = [z[f"e{i}_l{j}"] for j in range(ent["leaves"])]
                entries.append((int(ent["level"]),
                                _state_from_parts(ent["kind"], ent["aux"],
                                                  leaves)))
        return meta["folded"], tuple(entries)
