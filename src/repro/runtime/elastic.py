"""Elastic scaling: re-mesh a checkpoint to a different device count.

Because checkpoints store unsharded leaves + the sharding is derived from
(config, mesh) at restore time, scaling from N to M devices is:

    rules_M   = axis_rules_for(cfg, mesh_M, ...)
    shard_M   = shardings_for_tree(shapes, axes, mesh_M, rules_M)
    state     = checkpoint.restore(dir, step, like, shard_M)

``replan`` wraps that; tests verify a train state saved on a (4,) mesh
restores and keeps training on (2,) and (8,) meshes bit-identically.
"""
from __future__ import annotations

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.parallel.sharding import axis_rules_for, shardings_for_tree


def replan(cfg, new_mesh, shape_kind, batch_size, seq_len, shapes_tree,
           axes_tree):
    rules = axis_rules_for(cfg, new_mesh, shape_kind, batch_size=batch_size,
                           seq_len=seq_len)
    return rules, shardings_for_tree(shapes_tree, axes_tree, new_mesh, rules)


def restore_elastic(ckpt_dir: str, step: int, like_tree, cfg, new_mesh,
                    shape_kind: str, batch_size: int, seq_len: int,
                    axes_tree):
    shapes = jax.eval_shape(lambda: like_tree)
    _, shardings = replan(cfg, new_mesh, shape_kind, batch_size, seq_len,
                          shapes, axes_tree)
    return ckpt.restore(ckpt_dir, step, like_tree, shardings)
