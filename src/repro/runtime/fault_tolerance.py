"""Fault tolerance: heartbeats, straggler detection, restartable step loop.

At 1000+ nodes the failure model is: a host disappears (hardware), a step
hangs (network), or a step is abnormally slow (straggler).  The runtime
pieces here are host-side and framework-agnostic:

- :class:`Heartbeat` — per-host liveness file + stale-detection (on real
  pods this is a distributed KV store; the protocol is identical);
- :class:`StragglerMonitor` — per-step deadline from a running latency
  percentile; flags ranks whose step time exceeds ``k × p50``;
- :func:`run_restartable` — the crash-only training driver: any exception
  → restore from the last committed checkpoint and continue; bounded
  restarts to avoid crash loops.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Callable, Optional

from repro.checkpoint import checkpoint as ckpt
from repro.obs.metrics import counter as _counter, gauge as _gauge


class Heartbeat:
    def __init__(self, dir_: str, host_id: int, interval_s: float = 10.0,
                 startup_grace_s: Optional[float] = None):
        self.dir = dir_
        self.host_id = host_id
        self.interval_s = interval_s
        # hosts that have never beaten are not stale during the startup
        # grace window (measured from monitor creation): at pod start
        # every peer's beat file is legitimately absent until its first
        # beat lands, and flagging them all would trigger an immediate
        # spurious re-elect.  A *corrupt* beat file is different — the
        # host did write, and wrote garbage — and stays stale at once.
        self.startup_grace_s = (3.0 * interval_s if startup_grace_s is None
                                else startup_grace_s)
        self._created = time.time()
        os.makedirs(dir_, exist_ok=True)

    def beat(self, step: int):
        path = os.path.join(self.dir, f"host_{self.host_id}.hb")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"t": time.time(), "step": step}, f)
        os.replace(tmp, path)
        _counter("liveness/beats").inc()

    def stale_hosts(self, num_hosts: int, timeout_s: float = 60.0):
        now = time.time()
        in_grace = now - self._created <= self.startup_grace_s
        stale = []
        for h in range(num_hosts):
            path = os.path.join(self.dir, f"host_{h}.hb")
            try:
                with open(path) as f:
                    t = json.load(f)["t"]
                if now - t > timeout_s:
                    stale.append(h)
            except FileNotFoundError:
                if not in_grace:  # never beat, and grace has lapsed
                    stale.append(h)
            except json.JSONDecodeError:
                stale.append(h)
        # the staleness the monitor last saw — obs.snapshot() surfaces it
        _gauge("liveness/stale_hosts").set(len(stale))
        return stale


class StragglerMonitor:
    """Flags steps slower than ``factor × running-median``."""

    def __init__(self, factor: float = 2.0, window: int = 50, warmup: int = 5):
        self.factor = factor
        self.times = deque(maxlen=window)
        self.warmup = warmup
        self.flagged = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                self.flagged.append((step, dt, med))
                _counter("liveness/straggler_flagged").inc()
                is_straggler = True
        self.times.append(dt)
        return is_straggler

    def median(self) -> Optional[float]:
        if not self.times:
            return None
        return sorted(self.times)[len(self.times) // 2]


def _aligned_batches(batches, step: int):
    """An iterator positioned at batch ``step`` — step N consumes batch N.

    Restart alignment: after restoring step N the driver must NOT replay
    batches 0..N-1 (re-iterating a list from scratch would feed batch 0
    to step N).  Seekable sources (a ``seek(step)`` method) jump
    directly; re-iterable sources (lists, datasets) fast-forward by
    consuming ``step`` items; one-shot streams (generators — where
    ``iter(batches) is batches``) cannot rewind and are returned as-is,
    which is already aligned *within a process* (the stream sits past
    the batches consumed before the crash) but cannot replay the
    uncommitted tail — pass a seekable/re-iterable source when exact
    batch/step pairing across restarts matters.
    """
    if hasattr(batches, "seek"):
        batches.seek(step)
        return iter(batches)
    it = iter(batches)
    if it is batches:
        return it
    for _ in range(step):
        try:
            next(it)
        except StopIteration:
            raise ValueError(
                f"batch source exhausted while fast-forwarding to the "
                f"restored step {step}; it must yield at least {step} "
                f"batches to resume") from None
    return it


def run_restartable(
    step_fn: Callable,           # (state, batch) -> state
    init_state_fn: Callable,     # () -> state   (fresh start)
    batches,                     # iterator of batches
    *,
    ckpt_dir: str,
    total_steps: int,
    save_every: int = 100,
    max_restarts: int = 3,
    state_to_tree: Callable = lambda s: s,
    tree_to_state: Callable = lambda t, like: t,
    shardings=None,
    on_step: Optional[Callable] = None,
):
    """Crash-only driver: exceptions roll back to the last committed step."""
    restarts = 0
    monitor = StragglerMonitor()
    while True:
        try:
            last = ckpt.latest_step(ckpt_dir)
            if last is not None:
                state = init_state_fn()
                tree = ckpt.restore(ckpt_dir, last,
                                    state_to_tree(state), shardings)
                state = tree_to_state(tree, state)
                step = last
            else:
                state = init_state_fn()
                step = 0
            it = _aligned_batches(batches, step)
            while step < total_steps:
                batch = next(it)
                t0 = time.time()
                state = step_fn(state, batch)
                dt = time.time() - t0
                step += 1
                monitor.observe(step, dt)
                if on_step:
                    on_step(step, state, dt)
                if step % save_every == 0 or step == total_steps:
                    ckpt.save(ckpt_dir, step, state_to_tree(state))
            return state, monitor
        except KeyboardInterrupt:
            raise
        except Exception:  # noqa: BLE001 — crash-only restart
            restarts += 1
            if restarts > max_restarts:
                raise
