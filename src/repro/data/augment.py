"""Melt-based data augmentation (the paper's own application domain).

Generic, rank-agnostic augmentations for modality frontends: adaptive
bilateral denoising (paper Eq. 3 / Fig. 3b) and curvature-based keypoint
boosting (Eq. 6).  These run on frame/patch tensors before embedding; they
are the production integration of ``repro.core.filters``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.filters import bilateral_filter, gaussian_curvature, gaussian_filter


def denoise(x: jax.Array, op_size: int = 5, sigma_d: float = 1.5,
            sigma_r="adaptive") -> jax.Array:
    """Adaptive bilateral denoise of one sample of any rank."""
    return bilateral_filter(x, op_size, sigma_d, sigma_r)


def denoise_batch(x: jax.Array, **kw) -> jax.Array:
    """Batched denoise over the leading dim — one melt for the whole stack
    (the batched engine path, DESIGN.md §3), not a per-sample vmap."""
    return bilateral_filter(x, kw.pop("op_size", 5), kw.pop("sigma_d", 1.5),
                            kw.pop("sigma_r", "adaptive"), batched=True, **kw)


def keypoint_boost(x: jax.Array, gain: float = 4.0) -> jax.Array:
    """Emphasize high-curvature (corner-like) regions, any rank."""
    k = gaussian_curvature(x)
    k = k / (jnp.max(jnp.abs(k)) + 1e-9)
    return x * (1.0 + gain * jnp.abs(k))


def smooth(x: jax.Array, op_size: int = 5, sigma: float = 1.0) -> jax.Array:
    return gaussian_filter(x, op_size, sigma)
