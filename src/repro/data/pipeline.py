"""Sharded token data pipeline.

Production posture: each host feeds only its addressable shard of the
global batch (``host_batch_slice``), double-buffered with a background
prefetch thread.  Sources: synthetic (seeded, for tests/benchmarks) or
memory-mapped token files (one ``.bin`` of uint16/uint32 tokens).

The melt-matrix tie-in (paper §3.2 / DESIGN.md §4): modality pre-processing
(e.g. denoising frame/patch inputs) runs through ``repro.data.augment``
which is built on the core melt filters.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Deterministic synthetic next-token data (zipfian unigrams + shift)."""

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab, self.batch, self.seq_len = vocab, batch, seq_len
        self._rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            toks = self._rng.choice(
                self.vocab, size=(self.batch, self.seq_len + 1), p=self._probs
            ).astype(np.int32)
            yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class TokenFileLM:
    """Memory-mapped flat token file → (tokens, targets) windows."""

    def __init__(self, path: str, vocab: int, batch: int, seq_len: int,
                 dtype=np.uint16, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab, self.batch, self.seq_len = vocab, batch, seq_len
        self._rng = np.random.default_rng(seed)

    def __iter__(self):
        n = len(self.tokens) - self.seq_len - 1
        while True:
            starts = self._rng.integers(0, n, size=self.batch)
            rows = np.stack([
                self.tokens[s : s + self.seq_len + 1] for s in starts
            ]).astype(np.int32)
            yield {"tokens": rows[:, :-1], "targets": rows[:, 1:]}


def host_batch_slice(global_batch: int, host_id: int, num_hosts: int):
    """Row range of the global batch owned by this host."""
    per = global_batch // num_hosts
    return slice(host_id * per, (host_id + 1) * per)


class Prefetcher:
    """Background-thread prefetch (double buffering) over a batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:  # propagate to consumer
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise self._err or StopIteration
        return item


def make_pipeline(cfg, shape, source: str = "synthetic", path: str = "",
                  seed: int = 0, prefetch: int = 2, augment_fn=None):
    """Build the host-local pipeline: source → (optional batch augment) →
    prefetch.  ``augment_fn`` maps a batch dict to a batch dict and runs on
    the prefetch thread, overlapping preprocessing with the train step —
    the hook for batched melt-filter modality preprocessing via
    ``repro.data.augment`` (one batched stencil dispatch per batch, not a
    per-sample python loop; DESIGN.md §3/§4)."""
    if source == "synthetic":
        base = SyntheticLM(cfg.vocab, shape.global_batch, shape.seq_len, seed)
    elif source == "file":
        base = TokenFileLM(path, cfg.vocab, shape.global_batch, shape.seq_len,
                           seed=seed)
    else:
        raise ValueError(source)
    it: Iterator = iter(base)
    if augment_fn is not None:
        it = map(augment_fn, it)
    return Prefetcher(it, prefetch)
