"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At multi-pod scale the 'pod' axis rides the slow DCN links; compressing the
gradient all-reduce 4× (f32→int8 with per-tensor scale) cuts the collective
term proportionally.  Residual quantization error is fed back into the next
step (error feedback guarantees convergence for smooth objectives).

Usage: the train step, instead of relying on pjit's implicit grad psum over
'pod', keeps per-pod gradients (shard_map over 'pod') and calls
``compressed_psum``; error state lives alongside optimizer state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 psum over ``axis_name``.

    Returns (mean_grad ≈ psum(grad)/n, new_err).  int8 payload crosses the
    link; scales (f32 scalars) are summed exactly.
    """
    g = grad.astype(jnp.float32) + err
    # agree on a shared scale first (scalar pmax — negligible traffic),
    # so the summed payloads share one codebook
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    new_err = g - q * scale
    # sum int32 payloads (int8 would overflow at >127 summands)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.axis_size(axis_name)
    mean = q_sum.astype(jnp.float32) * scale / n
    return mean.astype(grad.dtype), new_err


def init_error_state(grads_shape) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)
