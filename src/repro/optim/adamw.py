"""AdamW with ZeRO-sharded moments (moments inherit parameter sharding).

Pure-pytree implementation (no optax in this environment).  Moments are
created with the same logical axes as their parameters, so the FSDP 'data'
sharding of params automatically ZeRO-shards optimizer state.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, moment_dtype=None) -> AdamWState:
    """``moment_dtype``: e.g. jnp.bfloat16 to halve optimizer memory at
    100B+ scale (the update math still runs in f32)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype or p.dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or callable(step)."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    if grad_clip is not None:
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return new_p, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
