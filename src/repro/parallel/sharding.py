"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Model code names tensor dimensions with *logical* axes ("batch", "embed",
"qkv", "ff", "expert", "vocab", ...).  A :class:`LogicalRules` table maps
each logical axis to zero or more mesh axes; ``constrain`` applies a
``with_sharding_constraint`` when a mesh is active, and ``logical_to_spec``
builds the PartitionSpec trees for pjit in/out shardings.

The per-arch planner :func:`axis_rules_for` encodes the DP/FSDP/TP/EP/SP
decisions (see DESIGN.md §6), including the fallbacks for dimensions that do
not divide the fixed 16-way 'model' axis (e.g. 24-head archs use sequence
parallelism for attention instead of head-sharded TP, hymba's 50 SSD heads
shard the SSD head_dim instead of the head count).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_state = threading.local()


class LogicalRules:
    def __init__(self, table: Dict[str, MeshAxes], mesh_axis_sizes: Dict[str, int]):
        self.table = dict(table)
        self.mesh_axis_sizes = dict(mesh_axis_sizes)

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}")
        return self.table[logical]

    def size(self, logical: str) -> int:
        ax = self.mesh_axes(logical)
        if ax is None:
            return 1
        if isinstance(ax, str):
            ax = (ax,)
        n = 1
        for a in ax:
            n *= self.mesh_axis_sizes[a]
        return n


def set_rules(rules: Optional[LogicalRules]) -> None:
    _state.rules = rules


def get_rules() -> Optional[LogicalRules]:
    return getattr(_state, "rules", None)


def logical_to_spec(axes: Sequence[Optional[str]], rules: Optional[LogicalRules] = None) -> P:
    """Logical names → PartitionSpec.  A mesh axis may appear only once per
    spec; later logical axes that would reuse one are demoted to replicated
    (first-wins, e.g. the logits' 'vocab' beats 'seq_res' on 'model')."""
    rules = rules or get_rules()
    if rules is None:
        return P()
    used = set()
    out = []
    for a in reversed(axes):  # trailing dims win: params/logits shard cleanly
        m = rules.mesh_axes(a)
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        if any(x in used for x in ms):
            out.append(None)
        else:
            used.update(ms)
            out.append(m)
    return P(*reversed(out))


def constrain(x: jax.Array, *axes: Optional[str]):
    """Apply a logical sharding constraint if rules are active (no-op else).

    Dims that do not divide their mapped mesh extent are silently left
    unsharded (e.g. the S=1 slice fed to the LM head during prefill).
    """
    rules = get_rules()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{x.ndim} tensor")
    eff = [
        a if (a is not None and rules.size(a) > 0 and d % max(rules.size(a), 1) == 0)
        else None
        for a, d in zip(axes, x.shape)
    ]
    try:
        return jax.lax.with_sharding_constraint(x, logical_to_spec(eff, rules))
    except (ValueError, RuntimeError):
        # no mesh context (e.g. pure-CPU smoke test) — constraints are advisory
        return x


def _divisible(n: int, ways: int) -> bool:
    return ways > 0 and n % ways == 0


def axis_rules_for(
    cfg,
    mesh: Mesh,
    shape_kind: str = "train",
    batch_size: Optional[int] = None,
    seq_len: Optional[int] = None,
    overrides: Optional[Dict[str, MeshAxes]] = None,
) -> LogicalRules:
    """Plan logical→mesh rules for one (arch, shape, mesh) cell.

    Decisions (DESIGN.md §6):
    - batch    → all DP axes ('pod','data') when divisible, else fewer/none
    - embed    → 'data' (FSDP / ZeRO-3 parameter+optimizer sharding)
    - qkv      → 'model' when n_heads divides, else SP fallback: 'seq_act'
                 → 'model' (context parallel attention, KV all-gathered)
    - ff       → 'model' (Megatron TP)
    - expert   → 'model' when n_experts divides (EP), else experts stay
                 unsharded and 'ff_expert' → 'model' (expert-TP fallback)
    - ssd_head_dim → 'model' (SSD shards the head *dim*, never head count —
                 P is a free axis of every SSD einsum, so zero collectives)
    - vocab    → 'model'
    - cache_seq→ KV-cache sequence axis; sharded for decode shapes when the
                 batch can't cover the DP axes (long-context serving)
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = "model"
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]

    table: Dict[str, MeshAxes] = {}
    # --- batch ------------------------------------------------------------
    # TP/SP shards pay ~4 residual-sized collectives per layer (Megatron-SP
    # all-gather/reduce-scatter) — for ≤40B models that traffic dwarfs the
    # FSDP weight gathers of pure DP.  Fold 'model' into the DP axes when
    # the batch divides (measured 14-55× collective reduction; see
    # EXPERIMENTS.md §Perf).  SSM/hybrid trunks additionally avoid the
    # SSD-layout↔sequence-sharding thrash this way.
    model_in_batch = False
    candidates = [dp_axes]
    try:
        small_enough = cfg.total_params() <= 40e9
    except Exception:
        small_enough = False
    if small_enough and shape_kind in ("train", "prefill"):
        # the folded candidate must divide exactly — a partial fold that
        # drops 'data' but keeps 'model' would leave DP axes idle
        candidates = [dp_axes + (model,), dp_axes]
    chosen = None
    for ci, cand in enumerate(candidates):
        cand = list(cand)
        exact = ci == 0 and len(candidates) > 1
        while cand:
            ways = 1
            for a in cand:
                ways *= sizes[a]
            if batch_size is None or _divisible(batch_size, ways):
                break
            if exact:
                cand = []
                break
            cand.pop(0)
        if cand:
            chosen = tuple(cand)
            break
    table["batch"] = chosen
    model_in_batch = bool(chosen and model in chosen)
    # --- params ------------------------------------------------------------
    fsdp = "data" if "data" in sizes else None
    if getattr(cfg, "fsdp_pods", False) and "pod" in sizes and fsdp:
        fsdp = ("data", "pod")
    if model_in_batch and fsdp:
        # FSDP naturally extends over every DP axis — shard weights over
        # (data, model) too when d_model divides, else keep data-only
        cand = ("data", model) if isinstance(fsdp, str) else fsdp + (model,)
        ways = 1
        for a in cand:
            ways *= sizes[a]
        if _divisible(cfg.d_model, ways):
            fsdp = cand
    if shape_kind == "decode":
        # serving: keep weights resident (replicated over DP) when the
        # TP-sharded copy fits HBM — FSDP would re-gather them every token
        try:
            per_dev = cfg.total_params() * 4 / sizes[model]
        except Exception:  # paper_stencil-style configs
            per_dev = 0
        if per_dev <= 6e9:
            fsdp = None
    fsdp_ways = 1
    for a in ((fsdp,) if isinstance(fsdp, str) else (fsdp or ())):
        fsdp_ways *= sizes[a]
    # FSDP shards the d_model dim of weight matrices:
    table["embed"] = fsdp if _divisible(cfg.d_model, fsdp_ways) else None
    table["vocab"] = model if _divisible(cfg.vocab, sizes[model]) else None
    # input-embedding table: D over 'model' (local gather fwd, local
    # scatter-add bwd); a vocab-sharded table turns the lookup into a
    # full-table f32 scatter per device (3+ GiB on 131k vocabs)
    table["embed_tp"] = model if _divisible(cfg.d_model, sizes[model]) else None
    table["ff"] = model if _divisible(cfg.d_ff or 1, sizes[model]) else None
    table["layer"] = None
    table["norm"] = None
    # --- attention -----------------------------------------------------------
    tp_heads = _divisible(cfg.n_heads, sizes[model]) and not model_in_batch
    table["qkv"] = model if tp_heads else None
    table["heads"] = model if tp_heads else None
    kv_rep = cfg.n_kv and cfg.n_kv < sizes[model]
    table["kv_heads"] = model if (tp_heads and cfg.n_kv and _divisible(cfg.n_kv, sizes[model])) else None
    # SP fallback: shard attention activations along sequence
    table["seq_act"] = None if (tp_heads or model_in_batch) else model
    table["mla_latent"] = None  # latent is small; replicate
    # Megatron-style sequence sharding of the residual stream between layers
    # (bounds the scanned-carry activation memory at 64-layer depth)
    table["seq_res"] = (
        model
        if (shape_kind in ("train", "prefill") and seq_len
            and _divisible(seq_len, sizes[model])
            and not model_in_batch
            and getattr(cfg, "family", "") not in ("ssm", "hybrid"))
        else None
    )
    # --- MoE -------------------------------------------------------------------
    if cfg.n_experts:
        ep = _divisible(cfg.n_experts, sizes[model])
        table["expert"] = model if ep else None
        table["ff_expert"] = None if ep else (
            model if _divisible(cfg.expert_ff, sizes[model]) else None
        )
    else:
        table["expert"] = None
        table["ff_expert"] = None
    table["ff_shared"] = model if (cfg.shared_ff and _divisible(cfg.shared_ff, sizes[model])) else None
    # --- SSM ---------------------------------------------------------------------
    table["ssd_head"] = None
    table["ssd_head_dim"] = (
        model
        if (_divisible(cfg.ssm_head_dim or 1, sizes[model])
            and not model_in_batch and cfg.ssm_state)
        else None
    )
    table["ssd_state"] = None
    table["ssd_inner"] = None  # packed inner projections stay head-dim sharded
    # --- serving caches ---------------------------------------------------------
    # KV caches dominate serving HBM; shard their sequence axis over 'model'
    # (KV-head counts rarely divide a 16-way axis — spec dedup keeps
    # kv_heads when both apply).  Degenerate batches (long_500k B=1) also
    # spread over the DP axes the batch can't use.
    if shape_kind in ("decode", "prefill") and seq_len:
        axes_c = []
        if table["batch"] != dp_axes:
            axes_c += [a for a in dp_axes
                       if (table["batch"] is None or a not in table["batch"])]
        axes_c.append(model)
        ways = 1
        for a in axes_c:
            ways *= sizes[a]
        table["cache_seq"] = tuple(axes_c) if _divisible(seq_len, ways) else None
    else:
        table["cache_seq"] = None
    table["seq"] = None
    if model_in_batch:
        # 'model' is folded into the DP axes — no other logical axis may
        # claim it (a conflicting claim would demote the batch sharding via
        # spec dedup and replicate every activation)
        for key in ("ff", "ff_expert", "ff_shared", "qkv", "heads",
                    "kv_heads", "vocab", "embed_tp", "expert", "seq_act",
                    "seq_res", "ssd_head_dim", "cache_seq"):
            if table.get(key) == model:
                table[key] = None
            elif isinstance(table.get(key), tuple) and model in table[key]:
                table[key] = tuple(a for a in table[key] if a != model) or None
    if overrides:
        table.update(overrides)
    return LogicalRules(table, sizes)


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]], rules: LogicalRules) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules))


def spec_for_shape(shape, axes, rules: LogicalRules) -> P:
    """PartitionSpec with indivisible dims demoted to replicated."""
    eff = [
        a if (a is not None and d % max(rules.size(a), 1) == 0) else None
        for a, d in zip(axes, shape)
    ]
    return logical_to_spec(eff, rules)


def shardings_for_tree(shapes_tree, axes_tree, mesh: Mesh, rules: LogicalRules):
    """Twin (ShapeDtypeStruct tree, AxisNames tree) → NamedSharding tree."""
    from repro.models.layers import is_axes

    flat_s, tdef = jax.tree.flatten(shapes_tree)
    flat_a = tdef.flatten_up_to(jax.tree.map(lambda a: a, axes_tree, is_leaf=is_axes))
    out = [
        NamedSharding(mesh, spec_for_shape(s.shape, tuple(a), rules))
        for s, a in zip(flat_s, flat_a)
    ]
    return tdef.unflatten(out)
