"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

For configs that opt in (``pp_stages > 1``) the layer stack is split into S
stages; microbatches flow through stages with ``shard_map`` + ``ppermute``:
at tick t, stage s computes microbatch (t − s) and passes its activation to
stage s+1 — the classic GPipe schedule with S − 1 bubble ticks on each side.

The production (16,16)/(2,16,16) meshes keep PP off (depth fits via
FSDP+TP), but the substrate exists for deeper models / larger clusters and
is verified against sequential execution in tests/test_pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    mesh: Mesh,
    stage_axis: str,
    layer_fn: Callable,   # (params_one_stage, x_microbatch) -> x_microbatch
    stage_params,         # pytree, leaves with leading dim = n_stages
    x,                    # (n_micro, mb, ...) microbatched input
):
    """Run ``layer_fn`` as an S-stage pipeline.  Returns (n_micro, mb, ...).

    stage_params leaves are sharded (stage, ...); x is replicated.
    """
    S = mesh.shape[stage_axis]
    n_micro = x.shape[0]
    ticks = n_micro + S - 1

    def stage_fn(params, xs):
        params = jax.tree.map(lambda t: t[0], params)  # local stage params
        s = jax.lax.axis_index(stage_axis)

        def tick(carry, t):
            buf_in, outputs = carry
            # stage 0 injects microbatch t (when valid); others use buf_in
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = xs[mb_idx]
            cur = jnp.where(s == 0, inject, buf_in)
            y = layer_fn(params, cur)
            # pass to next stage; last stage's output is collected
            buf_next = jax.lax.ppermute(
                y, stage_axis, perm=[(i, i + 1) for i in range(S - 1)])
            out_idx = t - (S - 1)
            valid = (out_idx >= 0) & (s == S - 1)
            outputs = jax.lax.cond(
                valid.any() if hasattr(valid, "any") else valid,
                lambda o: o.at[jnp.clip(out_idx, 0, n_micro - 1)].set(y),
                lambda o: o,
                outputs,
            )
            return (buf_next, outputs), None

        buf0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, out0), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them
        outputs = jax.lax.psum(
            jnp.where(s == S - 1, outputs, jnp.zeros_like(outputs)),
            stage_axis)
        return outputs

    spec_p = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(spec_p, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)


def sequential_reference(layer_fn, stage_params, x):
    """What the pipeline must equal: stages applied in order."""
    S = jax.tree.leaves(stage_params)[0].shape[0]

    def apply_all(xmb):
        for s in range(S):
            p = jax.tree.map(lambda t: t[s], stage_params)
            xmb = layer_fn(p, xmb)
        return xmb

    return jax.vmap(apply_all)(x)
