from repro.parallel.sharding import (
    LogicalRules,
    axis_rules_for,
    constrain,
    logical_to_spec,
    set_rules,
    get_rules,
)

__all__ = [
    "LogicalRules",
    "axis_rules_for",
    "constrain",
    "logical_to_spec",
    "set_rules",
    "get_rules",
]
